//! Artifact manifest: metadata for the AOT-compiled HLO programs written
//! by `python/compile/aot.py` (`artifacts/manifest.json`).

use std::path::{Path, PathBuf};

use crate::types::{FsError, Result};
use crate::util::json::Json;

/// Plan variant of an artifact (paper §3.1.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Optimized DSL plan (Pallas rolling kernel).
    Dsl,
    /// Black-box-UDF baseline plan (per-bin recompute).
    Naive,
}

impl Variant {
    pub fn parse(s: &str) -> Result<Variant> {
        match s {
            "dsl" => Ok(Variant::Dsl),
            "naive" => Ok(Variant::Naive),
            other => Err(FsError::Artifact(format!("unknown variant '{other}'"))),
        }
    }
    pub fn as_str(self) -> &'static str {
        match self {
            Variant::Dsl => "dsl",
            Variant::Naive => "naive",
        }
    }
}

/// One AOT-compiled program: rolling aggregation at a fixed
/// `[entities, time_bins]` shape with a fixed window.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub shape: String,
    pub variant: Variant,
    pub file: PathBuf,
    pub entities: usize,
    pub time_bins: usize,
    pub window: usize,
    pub entity_block: usize,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    /// Padded time axis the program expects: `T + W - 1`.
    pub fn padded_bins(&self) -> usize {
        self.time_bins + self.window - 1
    }

    /// Can this artifact serve a workload of `e` entities × `t` bins with
    /// window `w`? (window must match exactly; shape must fit).
    pub fn fits(&self, e: usize, t: usize, w: usize) -> bool {
        self.window == w && self.entities >= e && self.time_bins >= t
    }

    /// Cost proxy for choosing the smallest fitting artifact.
    pub fn cells(&self) -> usize {
        self.entities * self.padded_bins()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            FsError::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| FsError::Artifact(e.to_string()))?;
        if v.get("format").as_i64() != Some(1) {
            return Err(FsError::Artifact("unsupported manifest format".into()));
        }
        let arr = v
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| FsError::Artifact("manifest missing 'artifacts'".into()))?;
        let mut artifacts = Vec::new();
        for a in arr {
            let req_str = |k: &str| -> Result<String> {
                a.get(k)
                    .as_str()
                    .map(str::to_string)
                    .ok_or_else(|| FsError::Artifact(format!("artifact missing '{k}'")))
            };
            let req_usize = |k: &str| -> Result<usize> {
                a.get(k)
                    .as_usize()
                    .ok_or_else(|| FsError::Artifact(format!("artifact missing '{k}'")))
            };
            let strings = |k: &str| -> Vec<String> {
                a.get(k)
                    .as_arr()
                    .map(|xs| xs.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
                    .unwrap_or_default()
            };
            artifacts.push(ArtifactSpec {
                name: req_str("name")?,
                shape: req_str("shape")?,
                variant: Variant::parse(&req_str("variant")?)?,
                file: dir.join(req_str("file")?),
                entities: req_usize("entities")?,
                time_bins: req_usize("time_bins")?,
                window: req_usize("window")?,
                entity_block: req_usize("entity_block")?,
                inputs: strings("inputs"),
                outputs: strings("outputs"),
            });
        }
        if artifacts.is_empty() {
            return Err(FsError::Artifact("manifest lists no artifacts".into()));
        }
        Ok(Manifest { dir, artifacts })
    }

    /// Smallest artifact of `variant` fitting `e × t` with window `w`.
    pub fn select(&self, variant: Variant, e: usize, t: usize, w: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.variant == variant && a.fits(e, t, w))
            .min_by_key(|a| a.cells())
            .ok_or_else(|| {
                FsError::Artifact(format!(
                    "no {} artifact fits workload e={e} t={t} window={w}; available: {}",
                    variant.as_str(),
                    self.artifacts
                        .iter()
                        .map(|a| format!("{}(e={},t={},w={})", a.name, a.entities, a.time_bins, a.window))
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    /// Largest-capacity artifact for `(variant, window)` — the chunking
    /// target when no artifact holds the whole workload.
    pub fn select_largest(&self, variant: Variant, w: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.variant == variant && a.window == w)
            .max_by_key(|a| a.cells())
            .ok_or_else(|| {
                FsError::Artifact(format!(
                    "no {} artifact compiled for window={w}; available windows: {:?}",
                    variant.as_str(),
                    self.windows()
                ))
            })
    }

    pub fn by_name(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| FsError::NotFound(format!("artifact '{name}'")))
    }

    /// Distinct windows supported by the artifact set.
    pub fn windows(&self) -> Vec<usize> {
        let mut ws: Vec<usize> = self.artifacts.iter().map(|a| a.window).collect();
        ws.sort();
        ws.dedup();
        ws
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1, "dtype": "f32",
      "artifacts": [
        {"name":"small_dsl","shape":"small","variant":"dsl","file":"a.hlo.txt",
         "entities":16,"time_bins":32,"window":4,"entity_block":8,
         "inputs":["bin_sum","bin_cnt","bin_min","bin_max"],
         "outputs":["sum","cnt","mean","min","max"]},
        {"name":"big_dsl","shape":"big","variant":"dsl","file":"b.hlo.txt",
         "entities":64,"time_bins":128,"window":4,"entity_block":8,
         "inputs":[],"outputs":[]},
        {"name":"small_naive","shape":"small","variant":"naive","file":"c.hlo.txt",
         "entities":16,"time_bins":32,"window":4,"entity_block":8,
         "inputs":[],"outputs":[]}
      ]
    }"#;

    fn manifest() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap()
    }

    #[test]
    fn parses_fields() {
        let m = manifest();
        assert_eq!(m.artifacts.len(), 3);
        let a = m.by_name("small_dsl").unwrap();
        assert_eq!(a.padded_bins(), 35);
        assert_eq!(a.variant, Variant::Dsl);
        assert_eq!(a.outputs.len(), 5);
        assert!(a.file.starts_with("/tmp/a"));
    }

    #[test]
    fn select_prefers_smallest_fit() {
        let m = manifest();
        assert_eq!(m.select(Variant::Dsl, 10, 20, 4).unwrap().name, "small_dsl");
        assert_eq!(m.select(Variant::Dsl, 20, 20, 4).unwrap().name, "big_dsl");
        assert_eq!(m.select(Variant::Naive, 16, 32, 4).unwrap().name, "small_naive");
    }

    #[test]
    fn select_requires_exact_window() {
        let m = manifest();
        assert!(m.select(Variant::Dsl, 4, 4, 5).is_err());
    }

    #[test]
    fn select_rejects_oversize() {
        let m = manifest();
        assert!(m.select(Variant::Dsl, 65, 10, 4).is_err());
        assert!(m.select(Variant::Dsl, 10, 129, 4).is_err());
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse(r#"{"format":2,"artifacts":[]}"#, PathBuf::new()).is_err());
        assert!(Manifest::parse(r#"{"format":1,"artifacts":[]}"#, PathBuf::new()).is_err());
        assert!(Manifest::parse(
            r#"{"format":1,"artifacts":[{"name":"x"}]}"#,
            PathBuf::new()
        )
        .is_err());
    }

    #[test]
    fn windows_deduped() {
        assert_eq!(manifest().windows(), vec![4]);
    }
}
