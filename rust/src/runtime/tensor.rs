//! Dense row-major f32 tensors and padding to artifact shapes.
//!
//! The binning stage produces `[E, T]` per-bin partial aggregates for the
//! *actual* workload; AOT programs have *static* shapes, so inputs are
//! padded up to the selected artifact's `[E_a, T_a + W - 1]` and outputs
//! trimmed back. Padding values are the aggregation identities (0 for
//! sum/cnt, ±inf for min/max) so padded cells never contaminate results.

/// Row-major 2-D f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Tensor2 { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self::filled(rows, cols, 0.0)
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Tensor2 { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Pad to `[rows_to, cols_to]` with `fill`, placing `self` at offset
    /// `(0, col_off)` — used to attach the halo region on the left and
    /// grow to artifact shape on the right/bottom.
    pub fn pad_into(&self, rows_to: usize, cols_to: usize, col_off: usize, fill: f32) -> Tensor2 {
        assert!(rows_to >= self.rows && cols_to >= self.cols + col_off);
        let mut out = Tensor2::filled(rows_to, cols_to, fill);
        for r in 0..self.rows {
            out.row_mut(r)[col_off..col_off + self.cols].copy_from_slice(self.row(r));
        }
        out
    }

    /// Copy out an arbitrary `[rows, cols]` sub-block.
    pub fn slice(&self, rows: std::ops::Range<usize>, cols: std::ops::Range<usize>) -> Tensor2 {
        assert!(rows.end <= self.rows && cols.end <= self.cols);
        let mut out = Tensor2::zeros(rows.len(), cols.len());
        for (ro, ri) in rows.clone().enumerate() {
            out.row_mut(ro).copy_from_slice(&self.row(ri)[cols.clone()]);
        }
        out
    }

    /// Write `block` into this tensor at offset `(r0, c0)`.
    pub fn write_block(&mut self, block: &Tensor2, r0: usize, c0: usize) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for r in 0..block.rows {
            self.row_mut(r0 + r)[c0..c0 + block.cols].copy_from_slice(block.row(r));
        }
    }

    /// Trim to the leading `[rows_to, cols_to]` block.
    pub fn trim(&self, rows_to: usize, cols_to: usize) -> Tensor2 {
        assert!(rows_to <= self.rows && cols_to <= self.cols);
        let mut out = Tensor2::zeros(rows_to, cols_to);
        for r in 0..rows_to {
            out.row_mut(r).copy_from_slice(&self.row(r)[..cols_to]);
        }
        out
    }
}

/// The four per-bin partial-aggregate planes produced by binning and
/// consumed by the rolling program (matching `manifest.inputs`).
#[derive(Debug, Clone)]
pub struct BinPlanes {
    pub sum: Tensor2,
    pub cnt: Tensor2,
    pub min: Tensor2,
    pub max: Tensor2,
}

impl BinPlanes {
    pub fn empty(entities: usize, bins: usize) -> Self {
        BinPlanes {
            sum: Tensor2::zeros(entities, bins),
            cnt: Tensor2::zeros(entities, bins),
            min: Tensor2::filled(entities, bins, f32::INFINITY),
            max: Tensor2::filled(entities, bins, f32::NEG_INFINITY),
        }
    }

    pub fn entities(&self) -> usize {
        self.sum.rows
    }

    pub fn bins(&self) -> usize {
        self.sum.cols
    }

    /// Record one event value into bin `b` of entity `e`.
    pub fn add_event(&mut self, e: usize, b: usize, v: f32) {
        self.sum.set(e, b, self.sum.get(e, b) + v);
        self.cnt.set(e, b, self.cnt.get(e, b) + 1.0);
        self.min.set(e, b, self.min.get(e, b).min(v));
        self.max.set(e, b, self.max.get(e, b).max(v));
    }

    /// Copy out a `[rows, cols]` sub-window of all planes (used by the
    /// engine's chunked execution).
    pub fn slice(&self, rows: std::ops::Range<usize>, cols: std::ops::Range<usize>) -> BinPlanes {
        BinPlanes {
            sum: self.sum.slice(rows.clone(), cols.clone()),
            cnt: self.cnt.slice(rows.clone(), cols.clone()),
            min: self.min.slice(rows.clone(), cols.clone()),
            max: self.max.slice(rows, cols),
        }
    }

    /// Pad all planes to the artifact's `[e_a, padded_bins]` shape with
    /// per-plane identity fills. The workload's own (already binned) halo
    /// is expected to be part of `self`; this only grows the shape.
    pub fn pad_to(&self, e_a: usize, padded_bins: usize) -> BinPlanes {
        BinPlanes {
            sum: self.sum.pad_into(e_a, padded_bins, 0, 0.0),
            cnt: self.cnt.pad_into(e_a, padded_bins, 0, 0.0),
            min: self.min.pad_into(e_a, padded_bins, 0, f32::INFINITY),
            max: self.max.pad_into(e_a, padded_bins, 0, f32::NEG_INFINITY),
        }
    }
}

/// The five rolling aggregation planes returned by the program
/// (matching `manifest.outputs`): sum, cnt, mean, min, max — `[E, T]`.
#[derive(Debug, Clone)]
pub struct RollPlanes {
    pub sum: Tensor2,
    pub cnt: Tensor2,
    pub mean: Tensor2,
    pub min: Tensor2,
    pub max: Tensor2,
}

impl RollPlanes {
    /// Write a chunk's outputs into this (larger) result at `(r0, c0)`.
    pub fn write_block(&mut self, part: &RollPlanes, r0: usize, c0: usize) {
        self.sum.write_block(&part.sum, r0, c0);
        self.cnt.write_block(&part.cnt, r0, c0);
        self.mean.write_block(&part.mean, r0, c0);
        self.min.write_block(&part.min, r0, c0);
        self.max.write_block(&part.max, r0, c0);
    }

    pub fn trim(&self, e: usize, t: usize) -> RollPlanes {
        RollPlanes {
            sum: self.sum.trim(e, t),
            cnt: self.cnt.trim(e, t),
            mean: self.mean.trim(e, t),
            min: self.min.trim(e, t),
            max: self.max.trim(e, t),
        }
    }

    /// Feature vector for (entity e, output bin t) in the canonical
    /// aggregation order used by feature-set schemas.
    pub fn feature_vec(&self, e: usize, t: usize) -> [f32; 5] {
        [
            self.sum.get(e, t),
            self.cnt.get(e, t),
            self.mean.get(e, t),
            self.min.get(e, t),
            self.max.get(e, t),
        ]
    }
}

/// CPU reference implementation of the rolling program — used by unit
/// tests (so `cargo test` doesn't need PJRT for every module) and by the
/// rust-UDF baseline in the dsl_vs_udf bench.
pub fn rolling_reference(planes: &BinPlanes, window: usize) -> RollPlanes {
    let e = planes.entities();
    let t_pad = planes.bins();
    assert!(t_pad + 1 > window, "padded bins {t_pad} < window {window}");
    let t_out = t_pad - (window - 1);
    let mut out = RollPlanes {
        sum: Tensor2::zeros(e, t_out),
        cnt: Tensor2::zeros(e, t_out),
        mean: Tensor2::zeros(e, t_out),
        min: Tensor2::filled(e, t_out, f32::INFINITY),
        max: Tensor2::filled(e, t_out, f32::NEG_INFINITY),
    };
    for r in 0..e {
        for t in 0..t_out {
            let (mut s, mut c) = (0.0f32, 0.0f32);
            let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
            for w in 0..window {
                s += planes.sum.get(r, t + w);
                c += planes.cnt.get(r, t + w);
                mn = mn.min(planes.min.get(r, t + w));
                mx = mx.max(planes.max.get(r, t + w));
            }
            out.sum.set(r, t, s);
            out.cnt.set(r, t, c);
            out.mean.set(r, t, if c > 0.0 { s / c.max(1.0) } else { 0.0 });
            out.min.set(r, t, mn);
            out.max.set(r, t, mx);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_accessors() {
        let mut t = Tensor2::zeros(2, 3);
        t.set(1, 2, 5.0);
        assert_eq!(t.get(1, 2), 5.0);
        assert_eq!(t.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn pad_and_trim_roundtrip() {
        let t = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let p = t.pad_into(4, 5, 0, -1.0);
        assert_eq!(p.get(0, 0), 1.0);
        assert_eq!(p.get(3, 4), -1.0);
        assert_eq!(p.trim(2, 2), t);
    }

    #[test]
    fn pad_with_offset_places_halo() {
        let t = Tensor2::from_vec(1, 2, vec![7.0, 8.0]);
        let p = t.pad_into(1, 4, 1, 0.0);
        assert_eq!(p.data, vec![0.0, 7.0, 8.0, 0.0]);
    }

    #[test]
    fn add_event_accumulates() {
        let mut b = BinPlanes::empty(2, 3);
        b.add_event(0, 1, 2.0);
        b.add_event(0, 1, 4.0);
        assert_eq!(b.sum.get(0, 1), 6.0);
        assert_eq!(b.cnt.get(0, 1), 2.0);
        assert_eq!(b.min.get(0, 1), 2.0);
        assert_eq!(b.max.get(0, 1), 4.0);
        // untouched bins keep identities
        assert_eq!(b.min.get(0, 0), f32::INFINITY);
    }

    #[test]
    fn rolling_reference_window_math() {
        // 1 entity, window 2, padded bins 4 → 3 output bins.
        let mut b = BinPlanes::empty(1, 4);
        for (bin, v) in [(0, 1.0f32), (1, 2.0), (2, 3.0), (3, 4.0)] {
            b.add_event(0, bin, v);
        }
        let r = rolling_reference(&b, 2);
        assert_eq!(r.sum.row(0), &[3.0, 5.0, 7.0]);
        assert_eq!(r.cnt.row(0), &[2.0, 2.0, 2.0]);
        assert_eq!(r.mean.row(0), &[1.5, 2.5, 3.5]);
        assert_eq!(r.min.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(r.max.row(0), &[2.0, 3.0, 4.0]);
    }

    #[test]
    fn rolling_reference_empty_windows() {
        let b = BinPlanes::empty(1, 5);
        let r = rolling_reference(&b, 3);
        assert!(r.sum.row(0).iter().all(|&v| v == 0.0));
        assert!(r.mean.row(0).iter().all(|&v| v == 0.0));
        assert!(r.min.row(0).iter().all(|&v| v == f32::INFINITY));
    }

    #[test]
    fn padding_identities_do_not_leak() {
        let mut b = BinPlanes::empty(1, 4);
        b.add_event(0, 3, 10.0);
        let padded = b.pad_to(8, 9);
        let r = rolling_reference(&padded, 2);
        let trimmed = r.trim(1, 3);
        // Window over (bin2,bin3): only the event contributes.
        assert_eq!(trimmed.sum.get(0, 2), 10.0);
        assert_eq!(trimmed.min.get(0, 2), 10.0);
        assert_eq!(trimmed.max.get(0, 2), 10.0);
    }
}
