//! Runtime for the AOT rolling-aggregation artifacts.
//!
//! Two interchangeable backends behind one [`Engine`] API:
//!
//! * **PJRT** (`--features xla-pjrt`) — loads the AOT HLO-text artifacts
//!   and executes them through the `xla` crate (`PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `client.compile` → `execute`;
//!   see `/opt/xla-example/load_hlo/`). Executables are compiled once
//!   per artifact and cached. Requires the vendored `xla` crate, which
//!   is not part of the offline workspace.
//! * **Reference** (default) — executes the same manifest-declared
//!   programs with the in-process [`rolling_reference`] kernel. Shapes,
//!   artifact selection, padding, chunking and stats behave exactly as
//!   the PJRT backend, so every caller (and test) is backend-agnostic;
//!   the rolling program's semantics are identical by construction.
//!
//! Python is never involved at request time in either backend.

pub mod manifest;
pub mod service;
pub mod tensor;

// The PJRT backend needs the `xla` crate, which is not part of this
// offline workspace. Fail the build with a pointer instead of an
// E0433 deep inside the backend; delete this guard after vendoring
// `xla` and adding it to rust/Cargo.toml.
#[cfg(feature = "xla-pjrt")]
compile_error!(
    "the `xla-pjrt` feature requires vendoring the `xla` crate (PjRtClient) \
     into the workspace and declaring it in rust/Cargo.toml; see the module \
     docs in src/runtime/mod.rs"
);

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
#[cfg(not(feature = "xla-pjrt"))]
use std::collections::HashSet;
#[cfg(feature = "xla-pjrt")]
use std::collections::HashMap;
#[cfg(feature = "xla-pjrt")]
use std::sync::Arc;
use std::sync::Mutex;

pub use manifest::{ArtifactSpec, Manifest, Variant};
pub use service::{ComputeHandle, ComputeService};
pub use tensor::{rolling_reference, BinPlanes, RollPlanes, Tensor2};

use crate::types::{FsError, Result};

/// Execution statistics (exported into the monitoring subsystem).
#[derive(Debug, Default)]
pub struct EngineStats {
    pub executions: AtomicU64,
    pub compiles: AtomicU64,
    pub cells_processed: AtomicU64,
    pub exec_nanos: AtomicU64,
}

/// The compute engine: one backend + a cache of compiled executables
/// keyed by artifact name.
pub struct Engine {
    manifest: Manifest,
    pub stats: EngineStats,
    #[cfg(feature = "xla-pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "xla-pjrt")]
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    /// Reference backend's "compile" cache: artifact names validated
    /// against the manifest (keeps `stats.compiles` semantics identical
    /// to the PJRT backend).
    #[cfg(not(feature = "xla-pjrt"))]
    compiled: Mutex<HashSet<String>>,
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("backend", &Self::backend_name())
            .field("artifacts", &self.manifest.artifacts.len())
            .finish()
    }
}

impl Engine {
    pub fn backend_name() -> &'static str {
        if cfg!(feature = "xla-pjrt") {
            "pjrt-cpu"
        } else {
            "reference"
        }
    }

    /// Load the manifest from `dir` and initialize the backend.
    #[cfg(feature = "xla-pjrt")]
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| FsError::Runtime(format!("pjrt init: {e}")))?;
        log::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine {
            manifest,
            stats: EngineStats::default(),
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Load the manifest from `dir` (reference backend: no device init).
    #[cfg(not(feature = "xla-pjrt"))]
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        log::info!(
            "runtime: backend=reference artifacts={}",
            manifest.artifacts.len()
        );
        Ok(Engine {
            manifest,
            stats: EngineStats::default(),
            compiled: Mutex::new(HashSet::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) executable for an artifact.
    #[cfg(feature = "xla-pjrt")]
    fn executable(&self, spec: &ArtifactSpec) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(&spec.name) {
            return Ok(exe.clone());
        }
        let path = spec.file.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| FsError::Artifact(format!("parse {path}: {e}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| FsError::Artifact(format!("compile {}: {e}", spec.name)))?;
        self.stats.compiles.fetch_add(1, Ordering::Relaxed);
        log::info!("runtime: compiled artifact '{}'", spec.name);
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Reference-backend "compile": validate and cache the artifact name
    /// so compile accounting matches the PJRT backend.
    #[cfg(not(feature = "xla-pjrt"))]
    fn executable(&self, spec: &ArtifactSpec) -> Result<()> {
        let mut g = self.compiled.lock().unwrap();
        if g.insert(spec.name.clone()) {
            self.stats.compiles.fetch_add(1, Ordering::Relaxed);
            log::debug!("runtime: prepared artifact '{}' (reference backend)", spec.name);
        }
        Ok(())
    }

    /// Eagerly compile every artifact (used by `geofs serve` startup so
    /// the first materialization doesn't pay compile latency).
    pub fn warmup(&self) -> Result<()> {
        let specs: Vec<ArtifactSpec> = self.manifest.artifacts.clone();
        for spec in &specs {
            self.executable(spec)?;
        }
        Ok(())
    }

    /// Execute the rolling program on binned planes.
    ///
    /// `planes` is the workload-shaped `[E, T + W - 1]` input (halo
    /// already attached by the caller per Algorithm 1's source lookback).
    /// The engine selects the smallest fitting artifact of `variant`;
    /// workloads larger than any artifact's static shape are *batched*
    /// through it in entity × time chunks (time chunks re-read the halo
    /// overlap, exactly like the kernel's own BlockSpec halo).
    pub fn rolling(&self, variant: Variant, planes: &BinPlanes, window: usize) -> Result<RollPlanes> {
        let e = planes.entities();
        let t_pad = planes.bins();
        if t_pad < window {
            return Err(FsError::InvalidArg(format!(
                "planes have {t_pad} bins < window {window} (halo missing?)"
            )));
        }
        let t_out = t_pad - (window - 1);
        match self.manifest.select(variant, e, t_out, window) {
            Ok(spec) => {
                let spec = spec.clone();
                self.rolling_once(&spec, planes, e, t_out)
            }
            Err(_) => {
                // No artifact holds the whole workload: chunk through the
                // largest one for this (variant, window).
                let spec = self.manifest.select_largest(variant, window)?.clone();
                let mut out = RollPlanes {
                    sum: Tensor2::zeros(e, t_out),
                    cnt: Tensor2::zeros(e, t_out),
                    mean: Tensor2::zeros(e, t_out),
                    min: Tensor2::filled(e, t_out, f32::INFINITY),
                    max: Tensor2::filled(e, t_out, f32::NEG_INFINITY),
                };
                let halo = window - 1;
                let mut r0 = 0;
                while r0 < e {
                    let r1 = (r0 + spec.entities).min(e);
                    let mut c0 = 0;
                    while c0 < t_out {
                        let c1 = (c0 + spec.time_bins).min(t_out);
                        // Input slice covers the chunk's own halo.
                        let sub = planes.slice(r0..r1, c0..c1 + halo);
                        let part = self.rolling_once(&spec, &sub, r1 - r0, c1 - c0)?;
                        out.write_block(&part, r0, c0);
                        c0 = c1;
                    }
                    r0 = r1;
                }
                Ok(out)
            }
        }
    }

    /// One padded execution of `spec` over planes that fit within it
    /// (PJRT backend).
    #[cfg(feature = "xla-pjrt")]
    fn rolling_once(
        &self,
        spec: &ArtifactSpec,
        planes: &BinPlanes,
        e: usize,
        t_out: usize,
    ) -> Result<RollPlanes> {
        let exe = self.executable(spec)?;
        let padded = planes.pad_to(spec.entities, spec.padded_bins());
        let lit = |t: &Tensor2| -> Result<xla::Literal> {
            xla::Literal::vec1(&t.data)
                .reshape(&[t.rows as i64, t.cols as i64])
                .map_err(|e| FsError::Runtime(format!("reshape: {e}")))
        };
        let args = [lit(&padded.sum)?, lit(&padded.cnt)?, lit(&padded.min)?, lit(&padded.max)?];

        let t0 = std::time::Instant::now();
        let out = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| FsError::Runtime(format!("execute {}: {e}", spec.name)))?;
        let result = out[0][0]
            .to_literal_sync()
            .map_err(|e| FsError::Runtime(format!("fetch result: {e}")))?;
        self.stats.exec_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats.cells_processed.fetch_add((e * t_out) as u64, Ordering::Relaxed);

        // Lowered with return_tuple=True → a 5-tuple (sum,cnt,mean,min,max).
        let parts = result
            .to_tuple()
            .map_err(|e| FsError::Runtime(format!("untuple: {e}")))?;
        if parts.len() != 5 {
            return Err(FsError::Runtime(format!(
                "artifact {} returned {} outputs, expected 5",
                spec.name,
                parts.len()
            )));
        }
        let mut planes_out = Vec::with_capacity(5);
        for p in parts {
            let v = p
                .to_vec::<f32>()
                .map_err(|e| FsError::Runtime(format!("read output: {e}")))?;
            planes_out.push(Tensor2::from_vec(spec.entities, spec.time_bins, v));
        }
        let full = RollPlanes {
            sum: planes_out[0].clone(),
            cnt: planes_out[1].clone(),
            mean: planes_out[2].clone(),
            min: planes_out[3].clone(),
            max: planes_out[4].clone(),
        };
        Ok(full.trim(e, t_out))
    }

    /// One padded execution of `spec` (reference backend): identical
    /// padding/trim path, with [`rolling_reference`] as the program body.
    #[cfg(not(feature = "xla-pjrt"))]
    fn rolling_once(
        &self,
        spec: &ArtifactSpec,
        planes: &BinPlanes,
        e: usize,
        t_out: usize,
    ) -> Result<RollPlanes> {
        self.executable(spec)?;
        let padded = planes.pad_to(spec.entities, spec.padded_bins());
        let t0 = std::time::Instant::now();
        let full = rolling_reference(&padded, spec.window);
        self.stats.exec_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats.cells_processed.fetch_add((e * t_out) as u64, Ordering::Relaxed);
        Ok(full.trim(e, t_out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Engine {
        Engine::load(artifacts_dir()).expect("artifacts/manifest.json must be present")
    }

    fn random_planes(rng: &mut Rng, e: usize, t_pad: usize) -> BinPlanes {
        let mut b = BinPlanes::empty(e, t_pad);
        for ei in 0..e {
            for bi in 0..t_pad {
                for _ in 0..rng.below(3) {
                    b.add_event(ei, bi, (rng.f32() - 0.5) * 20.0);
                }
            }
        }
        b
    }

    #[test]
    fn executes_and_matches_reference() {
        let eng = engine();
        let mut rng = Rng::new(42);
        let window = 4; // 'small' artifacts have window 4
        let planes = random_planes(&mut rng, 10, 20 + window - 1);
        let got = eng.rolling(Variant::Dsl, &planes, window).unwrap();
        let want = rolling_reference(&planes, window);
        assert_eq!(got.sum.rows, 10);
        assert_eq!(got.sum.cols, 20);
        for e in 0..10 {
            for t in 0..20 {
                for (g, w) in got.feature_vec(e, t).iter().zip(want.feature_vec(e, t)) {
                    if w.is_finite() {
                        assert!((g - w).abs() <= 1e-3 + w.abs() * 1e-4, "e={e} t={t} {g} vs {w}");
                    } else {
                        assert_eq!(*g, w);
                    }
                }
            }
        }
    }

    #[test]
    fn dsl_and_naive_variants_agree() {
        let eng = engine();
        let mut rng = Rng::new(7);
        let planes = random_planes(&mut rng, 16, 32 + 3);
        let a = eng.rolling(Variant::Dsl, &planes, 4).unwrap();
        let b = eng.rolling(Variant::Naive, &planes, 4).unwrap();
        // Same numerics modulo summation order (different fusion plans).
        let close = |x: &[f32], y: &[f32]| {
            x.iter().zip(y).all(|(a, b)| (a - b).abs() <= 1e-4 + b.abs() * 1e-5)
        };
        assert!(close(&a.sum.data, &b.sum.data));
        assert!(close(&a.mean.data, &b.mean.data));
        // min/max are order-insensitive: exact.
        assert_eq!(a.min.data, b.min.data);
        assert_eq!(a.max.data, b.max.data);
    }

    #[test]
    fn executable_cache_hits() {
        let eng = engine();
        let mut rng = Rng::new(1);
        let planes = random_planes(&mut rng, 4, 8 + 3);
        eng.rolling(Variant::Dsl, &planes, 4).unwrap();
        eng.rolling(Variant::Dsl, &planes, 4).unwrap();
        eng.rolling(Variant::Dsl, &planes, 4).unwrap();
        assert_eq!(eng.stats.compiles.load(Ordering::Relaxed), 1);
        assert_eq!(eng.stats.executions.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn unknown_window_rejected() {
        let eng = engine();
        let planes = BinPlanes::empty(8, 50);
        assert!(eng.rolling(Variant::Dsl, &planes, 7).is_err());
    }

    #[test]
    fn oversized_workloads_are_chunked() {
        // 40 entities × 70 output bins with window 4: exceeds the 'small'
        // artifact (16×32) and no other artifact has w=4, so the engine
        // batches entity×time chunks. Must match the reference exactly at
        // every cell, including chunk boundaries.
        let eng = engine();
        let mut rng = Rng::new(77);
        let window = 4;
        let planes = random_planes(&mut rng, 40, 70 + window - 1);
        let got = eng.rolling(Variant::Dsl, &planes, window).unwrap();
        let want = rolling_reference(&planes, window);
        assert_eq!(got.sum.rows, 40);
        assert_eq!(got.sum.cols, 70);
        for e in 0..40 {
            for t in 0..70 {
                for (g, w) in got.feature_vec(e, t).iter().zip(want.feature_vec(e, t)) {
                    if w.is_finite() {
                        assert!((g - w).abs() <= 1e-3 + w.abs() * 1e-4, "e={e} t={t} {g} vs {w}");
                    } else {
                        assert_eq!(*g, w, "e={e} t={t}");
                    }
                }
            }
        }
        // Multiple executions of the same cached executable.
        assert!(eng.stats.executions.load(Ordering::Relaxed) >= 6);
        assert_eq!(eng.stats.compiles.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn missing_halo_rejected() {
        let eng = engine();
        let planes = BinPlanes::empty(8, 2);
        assert!(matches!(
            eng.rolling(Variant::Dsl, &planes, 4),
            Err(FsError::InvalidArg(_))
        ));
    }

    #[test]
    fn thirty_day_window_artifact_available() {
        // The paper's churn features need a 30-bin window (daily shape).
        let eng = engine();
        let mut rng = Rng::new(3);
        let planes = random_planes(&mut rng, 5, 10 + 29);
        let got = eng.rolling(Variant::Dsl, &planes, 30).unwrap();
        let want = rolling_reference(&planes, 30);
        for t in 0..10 {
            let g = got.sum.get(0, t);
            let w = want.sum.get(0, t);
            assert!((g - w).abs() <= 1e-2 + w.abs() * 1e-4);
        }
    }

    #[test]
    fn warmup_compiles_all_artifacts() {
        let eng = engine();
        eng.warmup().unwrap();
        assert_eq!(
            eng.stats.compiles.load(Ordering::Relaxed),
            eng.manifest().artifacts.len() as u64
        );
    }
}
