//! Compute substrate: thread-pool executor and retry policies.
//!
//! The paper's §3.1.5 "serverless" managed compute is modelled as a
//! fixed-size worker pool executing materialization tasks; tokio is not
//! available offline, so this is a small hand-built executor with
//! join-handle futures and graceful shutdown.

pub mod pool;
pub mod retry;

pub use pool::{JoinHandle, ThreadPool};
pub use retry::{retry_with, RetryPolicy};
