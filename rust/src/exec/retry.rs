//! Retry with exponential backoff (§3.1.3 "retry failed actions, create
//! alerts for non-recoverable failures").
//!
//! Backoff sleeps are *virtual* when a test clock is supplied — the
//! scheduler and the geo failover tests drive time deterministically.

use crate::types::Result;
#[cfg(test)]
use crate::types::FsError;
use crate::util::Clock;

#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Maximum attempts including the first (>= 1).
    pub max_attempts: u32,
    /// Backoff before retry k (0-based) is `base_secs << k`, capped.
    pub base_secs: i64,
    pub max_backoff_secs: i64,
    /// Only errors with `is_transient()` are retried.
    pub retry_permanent: bool,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 4, base_secs: 1, max_backoff_secs: 60, retry_permanent: false }
    }
}

impl RetryPolicy {
    pub fn none() -> Self {
        RetryPolicy { max_attempts: 1, ..Default::default() }
    }

    pub fn backoff_secs(&self, attempt: u32) -> i64 {
        (self.base_secs << attempt.min(32)).min(self.max_backoff_secs)
    }
}

/// Outcome of a retried operation, with attempt accounting for metrics.
#[derive(Debug)]
pub struct RetryOutcome<T> {
    pub value: T,
    pub attempts: u32,
}

/// Run `op` under `policy`, advancing `clock` by the backoff between
/// attempts (virtual time — no OS sleep).
pub fn retry_with<T>(
    policy: &RetryPolicy,
    clock: &Clock,
    mut op: impl FnMut(u32) -> Result<T>,
) -> Result<RetryOutcome<T>> {
    let mut attempt = 0;
    loop {
        match op(attempt) {
            Ok(value) => return Ok(RetryOutcome { value, attempts: attempt + 1 }),
            Err(e) => {
                let retryable = e.is_transient() || policy.retry_permanent;
                attempt += 1;
                if !retryable || attempt >= policy.max_attempts {
                    return Err(e);
                }
                clock.advance(policy.backoff_secs(attempt - 1));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flaky(fail_times: u32) -> impl FnMut(u32) -> Result<u32> {
        move |attempt| {
            if attempt < fail_times {
                Err(FsError::InjectedFault(format!("attempt {attempt}")))
            } else {
                Ok(attempt)
            }
        }
    }

    #[test]
    fn succeeds_first_try() {
        let c = Clock::fixed(0);
        let out = retry_with(&RetryPolicy::default(), &c, flaky(0)).unwrap();
        assert_eq!(out.attempts, 1);
        assert_eq!(c.now(), 0); // no backoff
    }

    #[test]
    fn retries_transient_until_success() {
        let c = Clock::fixed(0);
        let out = retry_with(&RetryPolicy::default(), &c, flaky(2)).unwrap();
        assert_eq!(out.attempts, 3);
        assert_eq!(c.now(), 1 + 2); // backoffs 1s, 2s
    }

    #[test]
    fn gives_up_after_max_attempts() {
        let c = Clock::fixed(0);
        let err = retry_with(&RetryPolicy::default(), &c, flaky(10)).unwrap_err();
        assert!(matches!(err, FsError::InjectedFault(_)));
        assert_eq!(c.now(), 1 + 2 + 4); // 3 backoffs for 4 attempts
    }

    #[test]
    fn permanent_errors_not_retried() {
        let c = Clock::fixed(0);
        let mut calls = 0;
        let err = retry_with(&RetryPolicy::default(), &c, |_| {
            calls += 1;
            Err::<(), _>(FsError::NotFound("x".into()))
        })
        .unwrap_err();
        assert!(matches!(err, FsError::NotFound(_)));
        assert_eq!(calls, 1);
    }

    #[test]
    fn backoff_capped() {
        let p = RetryPolicy { max_attempts: 20, base_secs: 1, max_backoff_secs: 8, ..Default::default() };
        assert_eq!(p.backoff_secs(0), 1);
        assert_eq!(p.backoff_secs(3), 8);
        assert_eq!(p.backoff_secs(10), 8);
    }
}
