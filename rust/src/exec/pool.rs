//! Fixed-size thread pool with joinable task handles.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
}

/// A fixed-size worker pool. Tasks are FIFO; `submit` returns a
/// `JoinHandle` that can be awaited (blocking) for the task's result.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let s = shared.clone();
                thread::Builder::new()
                    .name(format!("geofs-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers: handles }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Submit a closure; returns a handle yielding its result.
    pub fn submit<T, F>(&self, f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let state = Arc::new(HandleState {
            slot: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
        });
        let s2 = state.clone();
        let task: Task = Box::new(move || {
            // Catch panics so a poisoned task doesn't kill the worker.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
            let mut slot = s2.slot.lock().unwrap();
            *slot = match result {
                Ok(v) => SlotState::Done(v),
                Err(_) => SlotState::Panicked,
            };
            s2.cv.notify_all();
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(task);
        }
        self.shared.cv.notify_one();
        JoinHandle { state }
    }

    /// Submit a batch and wait for all results (order preserved).
    pub fn map<T, I, F>(&self, items: I, f: F) -> Vec<T>
    where
        T: Send + 'static,
        I: IntoIterator,
        I::Item: Send + 'static,
        F: Fn(I::Item) -> T + Send + Sync + Clone + 'static,
    {
        let handles: Vec<_> = items
            .into_iter()
            .map(|item| {
                let f = f.clone();
                self.submit(move || f(item))
            })
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        // Acquire the queue lock before notifying: a worker that observed
        // shutdown=false does so while holding the queue lock, so by the
        // time we get it here that worker is parked in `cv.wait` (which
        // released the lock) and the notification cannot be lost.
        drop(self.shared.queue.lock().unwrap());
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(s: Arc<Shared>) {
    loop {
        let task = {
            let mut q = s.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if *s.shutdown.lock().unwrap() {
                    return;
                }
                q = s.cv.wait(q).unwrap();
            }
        };
        task();
    }
}

enum SlotState<T> {
    Pending,
    Done(T),
    Panicked,
    Taken,
}

struct HandleState<T> {
    slot: Mutex<SlotState<T>>,
    cv: Condvar,
}

/// Blocking join handle for a submitted task.
pub struct JoinHandle<T> {
    state: Arc<HandleState<T>>,
}

impl<T> JoinHandle<T> {
    /// Block until the task finishes. Panics if the task panicked
    /// (propagating failure like `std::thread::JoinHandle`).
    pub fn join(self) -> T {
        let mut slot = self.state.slot.lock().unwrap();
        loop {
            match std::mem::replace(&mut *slot, SlotState::Taken) {
                SlotState::Done(v) => return v,
                SlotState::Panicked => panic!("task panicked"),
                SlotState::Pending => {
                    *slot = SlotState::Pending;
                    slot = self.state.cv.wait(slot).unwrap();
                }
                SlotState::Taken => unreachable!("join called twice"),
            }
        }
    }

    /// Non-blocking check.
    pub fn is_finished(&self) -> bool {
        !matches!(*self.state.slot.lock().unwrap(), SlotState::Pending)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_tasks_and_returns_values() {
        let pool = ThreadPool::new(4);
        let h = pool.submit(|| 21 * 2);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map(0..100u64, |i| i * i);
        assert_eq!(out, (0..100u64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn all_workers_participate() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let hs: Vec<_> = (0..64)
            .map(|_| {
                let c = counter.clone();
                pool.submit(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn panicked_task_does_not_kill_pool() {
        let pool = ThreadPool::new(1);
        let bad = pool.submit(|| panic!("boom"));
        let good = pool.submit(|| 7);
        assert!(std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| bad.join())).is_err());
        assert_eq!(good.join(), 7);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let h = pool.submit(|| 1);
        assert_eq!(h.join(), 1);
        drop(pool); // must not hang
    }

    #[test]
    fn is_finished() {
        let pool = ThreadPool::new(1);
        let h = pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(30)));
        assert!(!h.is_finished());
        std::thread::sleep(std::time::Duration::from_millis(80));
        assert!(h.is_finished());
        h.join();
    }
}
