//! Role-based access control + audit log.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::types::{FsError, Result, Timestamp};

/// Something that can be granted access.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Principal(pub String);

/// Built-in roles, ordered by privilege.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Role {
    /// Read feature values (online/offline retrieval).
    Consumer,
    /// Consumer + define/materialize feature sets.
    Producer,
    /// Producer + manage stores, grants, policies.
    Admin,
}

/// Actions checked against roles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    ReadFeatures,
    WriteAssets,
    Materialize,
    ManageStore,
    ManageGrants,
}

impl Action {
    fn minimum_role(self) -> Role {
        match self {
            Action::ReadFeatures => Role::Consumer,
            Action::WriteAssets | Action::Materialize => Role::Producer,
            Action::ManageStore | Action::ManageGrants => Role::Admin,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Action::ReadFeatures => "read_features",
            Action::WriteAssets => "write_assets",
            Action::Materialize => "materialize",
            Action::ManageStore => "manage_store",
            Action::ManageGrants => "manage_grants",
        }
    }
}

/// A grant: principal → role on a feature store, from a workspace
/// (spoke). `workspace_region` ≠ store region ⇒ cross-region access
/// (§4.1.2), which the geo layer routes accordingly.
#[derive(Debug, Clone, PartialEq)]
pub struct Grant {
    pub principal: Principal,
    pub store: String,
    pub role: Role,
    pub workspace: String,
    pub workspace_region: String,
}

#[derive(Debug, Clone)]
pub struct AuditEntry {
    pub at: Timestamp,
    pub principal: Principal,
    pub action: &'static str,
    pub resource: String,
    pub allowed: bool,
}

/// The RBAC engine + audit log.
#[derive(Debug, Default)]
pub struct Rbac {
    grants: Mutex<HashMap<(Principal, String), Grant>>,
    audit: Mutex<Vec<AuditEntry>>,
}

impl Rbac {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn grant(&self, grant: Grant) {
        self.grants
            .lock()
            .unwrap()
            .insert((grant.principal.clone(), grant.store.clone()), grant);
    }

    pub fn revoke(&self, principal: &Principal, store: &str) -> Result<()> {
        self.grants
            .lock()
            .unwrap()
            .remove(&(principal.clone(), store.to_string()))
            .map(|_| ())
            .ok_or_else(|| FsError::NotFound(format!("grant for {principal:?} on '{store}'")))
    }

    /// Check + audit. Returns the grant so callers can route by the
    /// workspace region.
    pub fn check(
        &self,
        principal: &Principal,
        store: &str,
        action: Action,
        now: Timestamp,
    ) -> Result<Grant> {
        let grants = self.grants.lock().unwrap();
        let grant = grants.get(&(principal.clone(), store.to_string()));
        let allowed = grant.map_or(false, |g| g.role >= action.minimum_role());
        self.audit.lock().unwrap().push(AuditEntry {
            at: now,
            principal: principal.clone(),
            action: action.name(),
            resource: store.to_string(),
            allowed,
        });
        match (grant, allowed) {
            (Some(g), true) => Ok(g.clone()),
            _ => Err(FsError::AccessDenied {
                principal: principal.0.clone(),
                action: action.name().to_string(),
                resource: store.to_string(),
            }),
        }
    }

    /// Spokes attached to a store (hub) — Fig 4's sharing view.
    pub fn spokes(&self, store: &str) -> Vec<Grant> {
        let mut out: Vec<Grant> = self
            .grants
            .lock()
            .unwrap()
            .values()
            .filter(|g| g.store == store)
            .cloned()
            .collect();
        out.sort_by(|a, b| a.workspace.cmp(&b.workspace));
        out
    }

    pub fn audit_log(&self) -> Vec<AuditEntry> {
        self.audit.lock().unwrap().clone()
    }

    pub fn denied_count(&self) -> usize {
        self.audit.lock().unwrap().iter().filter(|e| !e.allowed).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grant(p: &str, store: &str, role: Role, region: &str) -> Grant {
        Grant {
            principal: Principal(p.into()),
            store: store.into(),
            role,
            workspace: format!("{p}-ws"),
            workspace_region: region.into(),
        }
    }

    #[test]
    fn role_hierarchy_enforced() {
        let r = Rbac::new();
        r.grant(grant("alice", "fs1", Role::Consumer, "eastus"));
        r.grant(grant("bob", "fs1", Role::Producer, "eastus"));
        r.grant(grant("carol", "fs1", Role::Admin, "westeu"));

        let p = |s: &str| Principal(s.into());
        assert!(r.check(&p("alice"), "fs1", Action::ReadFeatures, 0).is_ok());
        assert!(r.check(&p("alice"), "fs1", Action::Materialize, 1).is_err());
        assert!(r.check(&p("bob"), "fs1", Action::Materialize, 2).is_ok());
        assert!(r.check(&p("bob"), "fs1", Action::ManageGrants, 3).is_err());
        assert!(r.check(&p("carol"), "fs1", Action::ManageGrants, 4).is_ok());
        // No grant at all.
        assert!(matches!(
            r.check(&p("mallory"), "fs1", Action::ReadFeatures, 5),
            Err(FsError::AccessDenied { .. })
        ));
    }

    #[test]
    fn grants_are_per_store() {
        let r = Rbac::new();
        r.grant(grant("alice", "fs1", Role::Admin, "eastus"));
        assert!(r.check(&Principal("alice".into()), "fs2", Action::ReadFeatures, 0).is_err());
    }

    #[test]
    fn revoke_removes_access() {
        let r = Rbac::new();
        let alice = Principal("alice".into());
        r.grant(grant("alice", "fs1", Role::Consumer, "eastus"));
        assert!(r.check(&alice, "fs1", Action::ReadFeatures, 0).is_ok());
        r.revoke(&alice, "fs1").unwrap();
        assert!(r.check(&alice, "fs1", Action::ReadFeatures, 1).is_err());
        assert!(r.revoke(&alice, "fs1").is_err());
    }

    #[test]
    fn audit_records_allowed_and_denied() {
        let r = Rbac::new();
        r.grant(grant("alice", "fs1", Role::Consumer, "eastus"));
        let alice = Principal("alice".into());
        let _ = r.check(&alice, "fs1", Action::ReadFeatures, 10);
        let _ = r.check(&alice, "fs1", Action::ManageStore, 11);
        let log = r.audit_log();
        assert_eq!(log.len(), 2);
        assert!(log[0].allowed && !log[1].allowed);
        assert_eq!(r.denied_count(), 1);
    }

    #[test]
    fn spokes_lists_cross_region_workspaces() {
        let r = Rbac::new();
        r.grant(grant("alice", "fs1", Role::Consumer, "eastus"));
        r.grant(grant("bob", "fs1", Role::Consumer, "westeu"));
        r.grant(grant("zed", "fs2", Role::Consumer, "eastus"));
        let spokes = r.spokes("fs1");
        assert_eq!(spokes.len(), 2);
        assert!(spokes.iter().any(|g| g.workspace_region == "westeu"));
    }

    #[test]
    fn grant_update_replaces_role() {
        let r = Rbac::new();
        let alice = Principal("alice".into());
        r.grant(grant("alice", "fs1", Role::Consumer, "eastus"));
        r.grant(grant("alice", "fs1", Role::Admin, "eastus"));
        assert!(r.check(&alice, "fs1", Action::ManageStore, 0).is_ok());
    }
}
