//! Feature governance (§2.1): RBAC and audit logging.
//!
//! Also carries the hub-and-spoke sharing model (§4.1.1): consuming
//! workspaces (spokes) are granted access to feature-store assets (the
//! hub), including cross-region grants (§4.1.2's access-control
//! mechanism, the one AzureML shipped).

pub mod rbac;

pub use rbac::{Action, AuditEntry, Grant, Principal, Rbac, Role};
