//! Feature–model lineage (§4.6).
//!
//! Challenges the paper calls out: scale (a model can use hundreds of
//! features) and cross-region visibility (the store lives in one region,
//! models deploy anywhere).  The graph keeps compact integer-interned
//! adjacency in both directions so "features of model" and "models using
//! feature" are O(degree), and every edge is tagged with the deployment
//! region so a global view can be assembled per region or across all.

use std::collections::{HashMap, HashSet};
use std::sync::RwLock;

use crate::query::spec::FeatureRef;
use crate::types::Timestamp;

/// One deployed model version.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelId {
    pub name: String,
    pub version: u32,
}

#[derive(Debug, Clone)]
pub struct LineageEdge {
    pub model: ModelId,
    pub feature: FeatureRef,
    /// Region the model is deployed in (may differ from the store's).
    pub region: String,
    pub recorded_at: Timestamp,
}

#[derive(Debug, Default)]
struct Graph {
    models: Vec<ModelId>,
    model_idx: HashMap<ModelId, usize>,
    features: Vec<FeatureRef>,
    feature_idx: HashMap<FeatureRef, usize>,
    /// model → (feature, region, at)
    uses: Vec<Vec<(usize, String, Timestamp)>>,
    /// feature → models
    used_by: Vec<Vec<usize>>,
}

/// Thread-safe lineage tracker.
#[derive(Debug, Default)]
pub struct Lineage {
    g: RwLock<Graph>,
}

impl Lineage {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `model` (deployed in `region`) uses `features`.
    /// Idempotent per (model, feature).
    pub fn record(&self, model: ModelId, features: &[FeatureRef], region: &str, at: Timestamp) {
        let mut g = self.g.write().unwrap();
        let mi = match g.model_idx.get(&model) {
            Some(&i) => i,
            None => {
                let i = g.models.len();
                g.models.push(model.clone());
                g.model_idx.insert(model, i);
                g.uses.push(Vec::new());
                i
            }
        };
        for f in features {
            let fi = match g.feature_idx.get(f) {
                Some(&i) => i,
                None => {
                    let i = g.features.len();
                    g.features.push(f.clone());
                    g.feature_idx.insert(f.clone(), i);
                    g.used_by.push(Vec::new());
                    i
                }
            };
            if !g.uses[mi].iter().any(|(existing, _, _)| *existing == fi) {
                g.uses[mi].push((fi, region.to_string(), at));
                g.used_by[fi].push(mi);
            }
        }
    }

    /// Features a model depends on (avoids the paper's "manual effort to
    /// cherry-pick features").
    pub fn features_of(&self, model: &ModelId) -> Vec<FeatureRef> {
        let g = self.g.read().unwrap();
        g.model_idx
            .get(model)
            .map(|&mi| g.uses[mi].iter().map(|(fi, _, _)| g.features[*fi].clone()).collect())
            .unwrap_or_default()
    }

    /// Models consuming a feature — the impact set of changing it.
    pub fn models_using(&self, feature: &FeatureRef) -> Vec<ModelId> {
        let g = self.g.read().unwrap();
        g.feature_idx
            .get(feature)
            .map(|&fi| g.used_by[fi].iter().map(|&mi| g.models[mi].clone()).collect())
            .unwrap_or_default()
    }

    /// Models consuming *any* feature of a feature set version — what
    /// must be validated before deleting/deprecating it.
    pub fn models_using_feature_set(&self, feature_set: &str, version: u32) -> Vec<ModelId> {
        let g = self.g.read().unwrap();
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        for (fi, f) in g.features.iter().enumerate() {
            if f.feature_set == feature_set && f.version == version {
                for &mi in &g.used_by[fi] {
                    if seen.insert(mi) {
                        out.push(g.models[mi].clone());
                    }
                }
            }
        }
        out
    }

    /// Global view (§4.6): per-region (models, edges) counts.
    pub fn global_view(&self) -> Vec<(String, usize, usize)> {
        let g = self.g.read().unwrap();
        let mut per_region: HashMap<String, (HashSet<usize>, usize)> = HashMap::new();
        for (mi, uses) in g.uses.iter().enumerate() {
            for (_, region, _) in uses {
                let e = per_region.entry(region.clone()).or_default();
                e.0.insert(mi);
                e.1 += 1;
            }
        }
        let mut out: Vec<_> = per_region
            .into_iter()
            .map(|(r, (models, edges))| (r, models.len(), edges))
            .collect();
        out.sort();
        out
    }

    pub fn edge_count(&self) -> usize {
        self.g.read().unwrap().uses.iter().map(Vec::len).sum()
    }

    pub fn model_count(&self) -> usize {
        self.g.read().unwrap().models.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(name: &str, v: u32) -> ModelId {
        ModelId { name: name.into(), version: v }
    }

    fn fref(s: &str) -> FeatureRef {
        FeatureRef::parse(s).unwrap()
    }

    #[test]
    fn bidirectional_lookup() {
        let l = Lineage::new();
        l.record(model("churn", 1), &[fref("txn:1:sum"), fref("txn:1:mean")], "eastus", 10);
        l.record(model("fraud", 3), &[fref("txn:1:sum")], "westeu", 20);

        assert_eq!(l.features_of(&model("churn", 1)).len(), 2);
        let users = l.models_using(&fref("txn:1:sum"));
        assert_eq!(users.len(), 2);
        assert!(l.models_using(&fref("txn:1:max")).is_empty());
        assert!(l.features_of(&model("nope", 1)).is_empty());
    }

    #[test]
    fn record_is_idempotent() {
        let l = Lineage::new();
        for _ in 0..3 {
            l.record(model("m", 1), &[fref("a:1:x")], "eastus", 5);
        }
        assert_eq!(l.edge_count(), 1);
        assert_eq!(l.models_using(&fref("a:1:x")).len(), 1);
    }

    #[test]
    fn feature_set_impact_analysis() {
        let l = Lineage::new();
        l.record(model("m1", 1), &[fref("txn:1:sum")], "eastus", 1);
        l.record(model("m2", 1), &[fref("txn:1:mean"), fref("txn:1:sum")], "eastus", 2);
        l.record(model("m3", 1), &[fref("txn:2:sum")], "eastus", 3);
        let impacted = l.models_using_feature_set("txn", 1);
        assert_eq!(impacted.len(), 2);
        assert_eq!(l.models_using_feature_set("txn", 2).len(), 1);
        assert!(l.models_using_feature_set("other", 1).is_empty());
    }

    #[test]
    fn cross_region_global_view() {
        let l = Lineage::new();
        l.record(model("m1", 1), &[fref("a:1:x"), fref("a:1:y")], "eastus", 1);
        l.record(model("m2", 1), &[fref("a:1:x")], "westeu", 2);
        let view = l.global_view();
        assert_eq!(view.len(), 2);
        assert!(view.contains(&("eastus".to_string(), 1, 2)));
        assert!(view.contains(&("westeu".to_string(), 1, 1)));
    }

    #[test]
    fn scales_to_hundreds_of_features_per_model() {
        let l = Lineage::new();
        let features: Vec<FeatureRef> =
            (0..500).map(|i| fref(&format!("fs{}:1:f{i}", i % 10))).collect();
        for m in 0..100 {
            l.record(model(&format!("m{m}"), 1), &features, "eastus", m as i64);
        }
        assert_eq!(l.model_count(), 100);
        assert_eq!(l.edge_count(), 100 * 500);
        assert_eq!(l.models_using(&features[0]).len(), 100);
        assert_eq!(l.features_of(&model("m42", 1)).len(), 500);
    }
}
