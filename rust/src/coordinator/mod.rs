//! The coordinator: the managed feature store facade (Fig 1 + Fig 2).
//!
//! [`FeatureStore`] wires every subsystem together — catalog, governance,
//! scheduler, materialization, dual stores, geo access, serving, lineage,
//! monitoring — behind the API the paper's SDK exposes: define assets,
//! materialize (scheduled + backfill), retrieve offline (PIT-correct)
//! and online (low-latency), bootstrap, fail over.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use crate::config::Config;
use crate::exec::ThreadPool;
use crate::geo::access::{CrossRegionAccess, ReadConsistency};
use crate::geo::replication::{ReplBatch, ReplicationDriver, ReplicationFabric, SessionToken};
use crate::geo::topology::GeoTopology;
use crate::governance::rbac::{Action, Principal, Rbac};
use crate::lineage::Lineage;
use crate::materialize::merge::{DualStoreMerger, FaultInjector};
use crate::materialize::Materializer;
use crate::metadata::assets::{EntitySpec, FeatureSetSpec, FeatureStoreSpec};
use crate::metadata::catalog::Catalog;
use crate::monitor::freshness::FreshnessTracker;
use crate::monitor::metrics::{MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::monitor::trace::{CompletedTrace, TraceConfig, Tracer};
use crate::offline_store::{persist_segment_to, CompactionDriver, OfflineStore, Segment, StoreConfig};
use crate::online_store::OnlineStore;
use crate::query::offline::{OfflineQueryEngine, TrainingFrame};
use crate::query::pit::{Observation, PitConfig};
use crate::query::spec::FeatureRef;
use crate::runtime::ComputeService;
use crate::monitor::sweeper::TtlSweeper;
use crate::scheduler::{JobOutcome, SchedulePolicy, Scheduler};
use crate::serving::router::{RouteTable, ServingRouter};
use crate::serving::service::OnlineServing;
use crate::source::SourceConnector;
use crate::storage::{
    DurableLog, DurableLogOptions, DurableStore, GcDriver, SegmentRef, SyncPolicy, Vfs,
};
use crate::stream::{
    CheckpointStore, EventLog, StreamConfig, StreamDeps, StreamEvent, StreamIngestor, StreamStats,
};
use crate::types::{EntityId, EntityInterner, FeatureWindow, FsError, Result, Timestamp};
use crate::util::backoff::{retry, Backoff};
use crate::util::json::Json;
use crate::util::Clock;

/// Where and how the store persists its write-ahead state. `None` in
/// [`OpenOptions::durability`] keeps the store RAM-only (the
/// pre-durability behavior — tests and benches that don't measure
/// crash-safety stay fast and filesystem-free).
#[derive(Clone)]
pub struct DurabilityOptions {
    /// Store directory: the manifest chain, WAL fragments and
    /// checkpointed `.gfseg` segments all live flat in here.
    pub dir: PathBuf,
    /// Filesystem seam — torture tests thread
    /// [`crate::testkit::faultfs::FaultFs`] through this.
    pub fs: Arc<dyn Vfs>,
    /// Roll the active WAL fragment once it exceeds this size.
    pub fragment_max_bytes: u64,
    /// The WAL ack protocol: per-frame fsync (default), group commit
    /// (one fsync covers a whole staged batch — amortized ack, same
    /// guarantee), or OS-managed flushing (no guarantee). E-DUR
    /// measures the trade.
    pub sync: SyncPolicy,
    /// Background snapshot-GC period; `None` leaves collection to
    /// explicit [`FeatureStore::gc_storage`] calls (deterministic
    /// tests drive passes by hand).
    pub gc_period: Option<std::time::Duration>,
}

impl DurabilityOptions {
    /// Durability at `dir` over the real filesystem, default knobs.
    pub fn at(dir: impl Into<PathBuf>) -> DurabilityOptions {
        let defaults = DurableLogOptions::default();
        DurabilityOptions {
            dir: dir.into(),
            fs: Arc::new(crate::storage::RealFs),
            fragment_max_bytes: defaults.fragment_max_bytes,
            sync: defaults.sync,
            gc_period: None,
        }
    }

    fn log_opts(&self) -> DurableLogOptions {
        DurableLogOptions {
            fragment_max_bytes: self.fragment_max_bytes,
            sync: self.sync,
            ..Default::default()
        }
    }
}

impl std::fmt::Debug for DurabilityOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DurabilityOptions")
            .field("dir", &self.dir)
            .field("fragment_max_bytes", &self.fragment_max_bytes)
            .field("sync", &self.sync)
            .field("gc_period", &self.gc_period)
            .finish_non_exhaustive()
    }
}

/// Options controlling how the store is opened.
#[derive(Debug, Clone)]
pub struct OpenOptions {
    /// Load the PJRT engine + AOT artifacts (true for anything that
    /// materializes via the optimized path).
    pub with_engine: bool,
    /// Engine threads in the compute service.
    pub compute_threads: usize,
    /// Enable geo-replication of the online store to all other regions.
    pub geo_replication: bool,
    /// Store is geo-fenced: replication disallowed (§4.1.2).
    pub geo_fenced: bool,
    /// Fault injection rates for the dual-store merger (tests/benches).
    pub fault_rates: Option<(u64, f64, f64)>,
    /// Admission policy for the serving front end. `None` = fully open
    /// (no gate constructed); `Some` wires an
    /// [`crate::serving::AdmissionController`] in front of every
    /// tenant-attributed online read.
    pub admission: Option<crate::serving::AdmissionConfig>,
    /// Request-tracing policy. The default (`sample_every: 0`) keeps
    /// every request untraced — the sampling check is a single field
    /// compare, no atomics — while still letting operators flip on
    /// 1-in-N sampling or the slow-op log without reopening the store's
    /// serving topology.
    pub trace: TraceConfig,
    /// Durable storage root (manifest-addressed WAL + snapshot GC).
    /// When set, the replication fabric and every stream log become
    /// write-ahead durable, [`FeatureStore::open`] recovers state from
    /// the newest valid manifest, and
    /// [`FeatureStore::checkpoint_durable`] replaces full-dump
    /// checkpointing.
    pub durability: Option<DurabilityOptions>,
}

impl Default for OpenOptions {
    fn default() -> Self {
        OpenOptions {
            with_engine: true,
            compute_threads: 2,
            geo_replication: false,
            geo_fenced: false,
            fault_rates: None,
            admission: None,
            trace: TraceConfig::default(),
            durability: None,
        }
    }
}

struct Registration {
    spec: FeatureSetSpec,
    source: Arc<dyn SourceConnector>,
    /// Start of the feature event timeline (scheduling origin).
    origin: Timestamp,
}

/// The managed geo-distributed feature store.
pub struct FeatureStore {
    pub config: Config,
    pub clock: Clock,
    pub catalog: Arc<Catalog>,
    pub rbac: Arc<Rbac>,
    pub lineage: Arc<Lineage>,
    pub metrics: Arc<MetricsRegistry>,
    /// Store-wide request tracer (policy from [`OpenOptions::trace`]):
    /// sampled traces from the serving path, the PIT engine, stream
    /// polls, and the background drivers all land in its rings —
    /// drain via [`FeatureStore::recent_traces`] /
    /// [`FeatureStore::slow_ops`].
    pub tracer: Arc<Tracer>,
    pub freshness: Arc<FreshnessTracker>,
    pub interner: Arc<EntityInterner>,
    pub scheduler: Arc<Scheduler>,
    pub offline: Arc<OfflineStore>,
    pub online: Arc<OnlineStore>,
    pub topology: Arc<GeoTopology>,
    pub serving: Arc<OnlineServing>,
    /// The serving admission gate, when configured via
    /// [`OpenOptions::admission`] (also reachable through
    /// `serving.admission`; kept here for operator rate overrides).
    pub admission: Option<Arc<crate::serving::AdmissionController>>,
    /// The replication fabric: one durable record log every home merge
    /// appends to, delivered to replica regions by the background
    /// driver. `None` when geo-replication is off.
    pub fabric: Option<Arc<ReplicationFabric>>,
    pub merger: Arc<DualStoreMerger>,
    /// Store-level consumer-group checkpoints: engines started via
    /// [`FeatureStore::start_stream`] commit here (via
    /// [`FeatureStore::checkpoint_stream`]), which lets their source
    /// logs truncate without caller-side plumbing.
    pub checkpoints: Arc<CheckpointStore>,
    /// The durable storage root when opened with
    /// [`OpenOptions::durability`]: manifest chain, WAL fragments and
    /// checkpointed segments. `None` = RAM-only store.
    pub durable: Option<Arc<DurableStore>>,
    /// Shared worker pool: scheduler jobs and the offline query engine's
    /// per-table / per-chunk PIT joins run here.
    pool: Arc<ThreadPool>,
    materializer: Arc<Materializer>,
    routes: Arc<RouteTable>,
    registrations: RwLock<HashMap<String, Arc<Registration>>>,
    /// Active streaming engines, one per streamed feature set (§4.3's
    /// streaming materialization plane).
    streams: RwLock<HashMap<String, Arc<StreamIngestor>>>,
    /// Background TTL sweep thread, when started.
    ttl_sweeper: RwLock<Option<TtlSweeper>>,
    /// Background offline-store compaction thread, when started: owns
    /// all tier merges so no writer (batch jobs, the stream dual-write)
    /// ever folds segments inline.
    compaction: RwLock<Option<CompactionDriver>>,
    /// Durable stream logs by table, kept across engine stop/start so
    /// a restarted stream re-attaches to its WAL instead of opening a
    /// second writer over the same fragment files.
    stream_logs: RwLock<HashMap<String, Arc<DurableLog<StreamEvent>>>>,
    /// The durability knobs the store was opened with (stream logs
    /// opened later need them).
    durability: Option<DurabilityOptions>,
    /// Background snapshot-GC thread, when configured.
    gc_driver: Option<GcDriver>,
    /// Background replication delivery thread (geo-replication only):
    /// woken by every fabric append, ticking for lag visibility. Lives
    /// for the store's lifetime.
    _repl_driver: Option<ReplicationDriver>,
    /// Keeps the compute threads alive for the store's lifetime.
    _compute: Option<ComputeService>,
    geo_fenced: bool,
    store_name: RwLock<Option<String>>,
}

impl FeatureStore {
    /// Open a feature store deployment.
    pub fn open(config: Config, opts: OpenOptions) -> Result<Arc<FeatureStore>> {
        let clock = Clock::fixed(0);
        let topology = config.topology();
        let pool = Arc::new(ThreadPool::new(config.workers));
        let interner = Arc::new(EntityInterner::new());
        let compute = if opts.with_engine {
            Some(ComputeService::start(&config.artifacts_dir, opts.compute_threads.max(1))?)
        } else {
            None
        };
        let engine = compute.as_ref().map(|c| c.handle());
        // Durable root first: the recovered manifest feeds the offline
        // store, the fabric and the checkpoint/coverage restores below.
        let durable = match &opts.durability {
            Some(d) => Some(DurableStore::open(d.fs.clone(), &d.dir, clock.now())?),
            None => None,
        };
        let manifest = durable.as_ref().map(|s| s.manifest());
        // With durability the offline store restores from the
        // manifest's checkpointed segment set — never a directory scan,
        // which would resurrect unreferenced segments awaiting GC.
        let offline = match (&manifest, &opts.durability) {
            (Some(m), Some(d)) if !m.segments.is_empty() => {
                let files: Vec<(String, PathBuf)> = m
                    .segments
                    .iter()
                    .map(|s| (s.table.clone(), d.dir.join(&s.file)))
                    .collect();
                Arc::new(OfflineStore::load_files(&files, StoreConfig::default())?)
            }
            _ => Arc::new(OfflineStore::new()),
        };
        let online = Arc::new(OnlineStore::new(config.online_shards));
        let faults = match opts.fault_rates {
            Some((seed, off_p, on_p)) => FaultInjector::with_rates(seed, off_p, on_p),
            None => FaultInjector::none(),
        };
        let merger = Arc::new(DualStoreMerger::new(
            offline.clone(),
            online.clone(),
            faults,
            config.retry.clone(),
            clock.clone(),
        ));
        let metrics = Arc::new(MetricsRegistry::new());
        let tracer = Tracer::new(opts.trace.clone());
        let fabric = if opts.geo_replication && !opts.geo_fenced && config.regions.len() > 1 {
            let replicas: Vec<_> = config
                .regions
                .iter()
                .filter(|r| *r != config.home_region())
                .map(|r| {
                    (
                        r.clone(),
                        Arc::new(OnlineStore::new(config.online_shards)),
                        config.replication_lag_secs,
                    )
                })
                .collect();
            let f = match (&durable, &opts.durability) {
                (Some(store), Some(d)) => {
                    let mut lo = d.log_opts();
                    lo.metrics = Some(metrics.clone());
                    lo.recovery_pool = Some(pool.clone());
                    let log = store.open_log::<ReplBatch>("fabric", 4, lo)?;
                    let f = ReplicationFabric::new_durable(log, replicas, Some(metrics.clone()));
                    if let Some(m) = &manifest {
                        // Recovered positions: per-region apply cursors
                        // and the checkpoint floor. The WAL tail above
                        // the cursors replays through the normal pump;
                        // state below them is in the checkpointed
                        // segments, which reach fresh replica stores
                        // via per-table `bootstrap_online_from_offline`
                        // (idempotent merges absorb the overlap).
                        for (region, cursors) in &m.cursors {
                            f.set_cursors(region, cursors);
                        }
                        if let Some(floor) = &m.checkpoint_floor {
                            f.set_checkpoint_floor(floor.clone());
                        }
                    }
                    f
                }
                _ => ReplicationFabric::new(4, replicas, Some(metrics.clone())),
            };
            Some(f)
        } else {
            None
        };
        // Background delivery: woken on every append, ticking so lagged
        // batches become visible as the clock advances. Regions apply
        // concurrently on the shared pool so a slow replica never
        // delays the others' convergence.
        let repl_driver = fabric.as_ref().map(|f| {
            ReplicationDriver::spawn_observed(
                f.clone(),
                clock.clone(),
                std::time::Duration::from_millis(20),
                pool.clone(),
                Some(tracer.clone()),
            )
        });
        let scheduler =
            Arc::new(Scheduler::new(pool.clone(), clock.clone(), config.retry.clone()));
        let checkpoints = Arc::new(CheckpointStore::new());
        if let Some(m) = &manifest {
            // Coverage + consumer cursors recorded by the last durable
            // checkpoint. Work done after that commit is deliberately
            // absent: the scheduler re-runs those windows and the
            // stream engines re-poll those offsets — at-least-once into
            // idempotent sinks.
            scheduler.restore(&m.coverage);
            if !matches!(m.consumer_checkpoints, Json::Null) {
                checkpoints.restore_entries(&m.consumer_checkpoints)?;
            }
        }
        let gc_driver = match (&durable, &opts.durability) {
            (Some(store), Some(d)) => {
                d.gc_period.map(|period| GcDriver::spawn(store.clone(), period))
            }
            _ => None,
        };
        // The offline store's tier merges are background-only now (no
        // inline compaction on any writer), so the managed store always
        // runs the driver; `stop_compaction` opts out.
        let compaction = CompactionDriver::spawn_observed(
            offline.clone(),
            std::time::Duration::from_millis(100),
            Some(metrics.clone()),
            Some(tracer.clone()),
        );
        let routes = Arc::new(RouteTable::new());
        let admission = opts
            .admission
            .as_ref()
            .map(|cfg| {
                crate::serving::AdmissionController::new(cfg.clone(), Some(metrics.clone()))
            });
        let mut serving = match &admission {
            Some(ctrl) => OnlineServing::with_admission(
                ServingRouter::new(routes.clone()),
                metrics.clone(),
                ctrl.clone(),
            ),
            None => OnlineServing::new(ServingRouter::new(routes.clone()), metrics.clone()),
        };
        serving.tracer = Some(tracer.clone());
        let serving = Arc::new(serving);
        Ok(Arc::new(FeatureStore {
            materializer: Arc::new(Materializer::new(engine, interner.clone())),
            pool,
            config,
            clock,
            catalog: Arc::new(Catalog::new()),
            rbac: Arc::new(Rbac::new()),
            lineage: Arc::new(Lineage::new()),
            metrics,
            tracer,
            freshness: Arc::new(FreshnessTracker::new()),
            interner,
            scheduler,
            offline,
            online,
            topology,
            serving,
            admission,
            fabric,
            merger,
            checkpoints,
            durable,
            routes,
            registrations: RwLock::new(HashMap::new()),
            streams: RwLock::new(HashMap::new()),
            ttl_sweeper: RwLock::new(None),
            compaction: RwLock::new(Some(compaction)),
            stream_logs: RwLock::new(HashMap::new()),
            durability: opts.durability.clone(),
            gc_driver,
            _repl_driver: repl_driver,
            _compute: compute,
            geo_fenced: opts.geo_fenced,
            store_name: RwLock::new(None),
        }))
    }

    // ---- asset management (§2.1) -------------------------------------------

    /// Create the feature store resource in the home region.
    pub fn create_store(&self, name: &str) -> Result<()> {
        self.catalog
            .create_store(FeatureStoreSpec::new(name, self.config.home_region()))?;
        *self.store_name.write().unwrap() = Some(name.to_string());
        Ok(())
    }

    fn store_name(&self) -> Result<String> {
        self.store_name
            .read()
            .unwrap()
            .clone()
            .ok_or_else(|| FsError::Other("no feature store created yet".into()))
    }

    pub fn create_entity(&self, spec: EntitySpec) -> Result<()> {
        self.catalog.create_entity(&self.store_name()?, spec)
    }

    /// Register a feature set: catalog entry + source binding + serving
    /// route + TTL + freshness SLA. `origin` anchors the scheduling
    /// timeline (earliest event time to materialize).
    pub fn register_feature_set(
        &self,
        spec: FeatureSetSpec,
        source: Arc<dyn SourceConnector>,
        origin: Timestamp,
    ) -> Result<String> {
        let store = self.store_name()?;
        self.catalog.create_feature_set(&store, spec.clone())?;
        let table = spec.reference();
        if spec.materialization.online_enabled {
            self.online.set_ttl(&table, spec.materialization.online_ttl_secs);
        }
        self.freshness.configure(
            &table,
            spec.source.source_delay_secs,
            spec.materialization.schedule_interval_secs,
        );
        self.routes.set(
            &table,
            Arc::new(CrossRegionAccess {
                topology: self.topology.clone(),
                home_region: self.config.home_region().to_string(),
                home_store: self.online.clone(),
                fabric: self.fabric.clone(),
                geo_fenced: self.geo_fenced,
            }),
        );
        self.registrations.write().unwrap().insert(
            table.clone(),
            Arc::new(Registration { spec, source, origin }),
        );
        Ok(table)
    }

    fn registration(&self, table: &str) -> Result<Arc<Registration>> {
        self.registrations
            .read()
            .unwrap()
            .get(table)
            .cloned()
            .ok_or_else(|| FsError::NotFound(format!("registered feature set '{table}'")))
    }

    pub fn feature_set_specs(&self) -> HashMap<String, FeatureSetSpec> {
        self.registrations
            .read()
            .unwrap()
            .values()
            .map(|r| (r.spec.name.clone(), r.spec.clone()))
            .collect()
    }

    // ---- materialization (§4.3) -------------------------------------------

    fn job_fn(&self, reg: &Arc<Registration>) -> crate::scheduler::executor::JobFn {
        let spec = reg.spec.clone();
        let source = reg.source.clone();
        let materializer = self.materializer.clone();
        let merger = self.merger.clone();
        let clock = self.clock.clone();
        let fabric = self.fabric.clone();
        let metrics = self.metrics.clone();
        let table = reg.spec.reference();
        Arc::new(move |window: FeatureWindow, _attempt: u32| {
            let now = clock.now();
            let records = materializer.calculate(&spec, source.as_ref(), window, now, now)?;
            let report = merger.merge(&table, &records, &spec.materialization, now)?;
            if let Some(f) = &fabric {
                // Durable appends can hit transient I/O; replica merges
                // are idempotent, so a retried (possibly duplicated)
                // append is safe. A persistent failure fails the job —
                // the scheduler re-runs the window.
                retry(&Backoff::default(), || f.append(&table, &records, now))?;
            }
            metrics.inc(MetricKind::System, names::MATERIALIZED_RECORDS, records.len() as u64);
            metrics.inc(MetricKind::System, names::MATERIALIZATION_JOBS, 1);
            let _ = report; // per-sink stats are surfaced via metrics
            Ok(records.len() as u64)
        })
    }

    /// Run one scheduled materialization tick for a feature set.
    pub fn materialize_tick(&self, table: &str) -> Result<Vec<JobOutcome>> {
        let reg = self.registration(table)?;
        let policy = SchedulePolicy::from_spec(&reg.spec);
        let outcomes = self.scheduler.tick(table, &policy, reg.origin, self.job_fn(&reg));
        self.after_jobs(table, &reg, &outcomes);
        Ok(outcomes)
    }

    /// One-time backfill over a user window (§4.3).
    pub fn backfill(&self, table: &str, window: FeatureWindow) -> Result<Vec<JobOutcome>> {
        let reg = self.registration(table)?;
        let policy = SchedulePolicy::from_spec(&reg.spec);
        let outcomes = self.scheduler.backfill(table, &policy, window, self.job_fn(&reg));
        self.after_jobs(table, &reg, &outcomes);
        Ok(outcomes)
    }

    fn after_jobs(&self, table: &str, reg: &Arc<Registration>, outcomes: &[JobOutcome]) {
        if outcomes.is_empty() {
            return;
        }
        // Advance freshness to the contiguous high-water mark.
        let hw = {
            let mut hw = reg.origin;
            for w in self.scheduler.coverage(table) {
                if w.start <= hw && w.end > hw {
                    hw = w.end;
                }
            }
            hw
        };
        self.freshness.advance(table, hw);
        // Deliver replicated data that has become visible (the driver
        // also runs in the background; per-region locks make the
        // concurrent pumps safe and the merges idempotent).
        if let Some(f) = &self.fabric {
            f.pump(self.clock.now());
        }
    }

    /// Drive replication delivery deterministically (geo examples and
    /// tests advance the simulated clock then pump): one fabric pump
    /// covers batch *and* streaming writes — they share the log — then
    /// the fully-applied prefix is reclaimed.
    pub fn pump_replication(&self) {
        if let Some(f) = &self.fabric {
            f.pump(self.clock.now());
            f.truncate_applied();
        }
    }

    /// The fabric positions covering every write acked so far — pass to
    /// [`ReadConsistency::ReadYourWrites`] to make replica reads wait
    /// for them. `None` without geo-replication (every read is home
    /// anyway).
    pub fn session_token(&self) -> Option<SessionToken> {
        self.fabric.as_ref().map(|f| f.token())
    }

    // ---- streaming ingestion (near-real-time materialization) -------------

    /// Start the streaming engine for a registered feature set: events
    /// appended via [`FeatureStore::stream_ingest`] materialize into
    /// both stores as the watermark passes each bin — milliseconds of
    /// poll latency instead of a scheduler period. Emitted batches are
    /// appended to the store's replication fabric (when replication is
    /// on), and the engine is wired to the coordinator-owned
    /// [`CheckpointStore`], so [`FeatureStore::checkpoint_stream`] +
    /// the per-poll retention pass keep the source log bounded without
    /// caller-side plumbing.
    pub fn start_stream(&self, table: &str, cfg: StreamConfig) -> Result<()> {
        let reg = self.registration(table)?;
        let mut streams = self.streams.write().unwrap();
        if streams.contains_key(table) {
            return Err(FsError::InvalidArg(format!("'{table}' is already streaming")));
        }
        let deps = StreamDeps {
            materializer: self.materializer.clone(),
            offline: self.offline.clone(),
            online: self.online.clone(),
            freshness: self.freshness.clone(),
            metrics: self.metrics.clone(),
            clock: self.clock.clone(),
            pool: Some(self.pool.clone()),
            fabric: self.fabric.clone(),
            checkpoints: Some(self.checkpoints.clone()),
            tracer: Some(self.tracer.clone()),
        };
        let ing = match (&self.durable, &self.durability) {
            (Some(store), Some(d)) => {
                if cfg.partitions == 0 {
                    return Err(FsError::InvalidArg("stream partitions must be > 0".into()));
                }
                // One WAL per table, cached across engine stop/start so
                // a restarted stream re-attaches instead of opening a
                // second writer over the same fragment files.
                let log = {
                    let mut logs = self.stream_logs.write().unwrap();
                    match logs.get(table) {
                        Some(l) => l.clone(),
                        None => {
                            let mut lo = d.log_opts();
                            lo.metrics = Some(self.metrics.clone());
                            lo.recovery_pool = Some(self.pool.clone());
                            let l = store.open_log::<StreamEvent>(
                                &format!("stream/{table}"),
                                cfg.partitions,
                                lo,
                            )?;
                            logs.insert(table.to_string(), l.clone());
                            l
                        }
                    }
                };
                let ing = StreamIngestor::with_log(
                    reg.spec.clone(),
                    cfg,
                    deps,
                    Arc::new(EventLog::durable(log)),
                )?;
                // Resume from recovered consumer checkpoints (no-op on
                // a fresh store): replay starts above the committed
                // offsets, not at the log head.
                ing.restore_from(&self.checkpoints)?;
                ing
            }
            _ => StreamIngestor::new(reg.spec.clone(), cfg, deps)?,
        };
        streams.insert(table.to_string(), ing);
        Ok(())
    }

    /// The running engine for `table` (ingest/poll/checkpoint surface).
    pub fn stream(&self, table: &str) -> Result<Arc<StreamIngestor>> {
        self.streams
            .read()
            .unwrap()
            .get(table)
            .cloned()
            .ok_or_else(|| FsError::NotFound(format!("streaming engine for '{table}'")))
    }

    /// Append events to a table's stream, through the engine's admission
    /// bound (`StreamConfig::max_backlog_events`): sheds with a typed
    /// `Overloaded` error rather than growing the backlog without bound.
    /// The default bound is unlimited, so nothing sheds until a stream
    /// is configured with one.
    pub fn stream_ingest(&self, table: &str, events: &[StreamEvent]) -> Result<u64> {
        self.stream(table)?.try_ingest(events)
    }

    /// Process everything currently in the table's log.
    pub fn poll_stream(&self, table: &str) -> Result<StreamStats> {
        self.stream(table)?.poll()
    }

    /// Poll to exhaustion and flush the online write stage.
    pub fn drain_stream(&self, table: &str) -> Result<StreamStats> {
        self.stream(table)?.drain()
    }

    /// Detach the engine, then drain it (its log lives only as long as
    /// the engine, so stop is a drain barrier). Detaching **first**
    /// makes the barrier atomic: an ingest racing with stop fails with
    /// `NotFound` instead of appending to a log that is about to be
    /// dropped (a silent data loss). If the final drain fails, the
    /// engine is re-attached so the caller can retry instead of losing
    /// the undrained log with the last `Arc`.
    pub fn stop_stream(&self, table: &str) -> Result<StreamStats> {
        let ing = self
            .streams
            .write()
            .unwrap()
            .remove(table)
            .ok_or_else(|| FsError::NotFound(format!("streaming engine for '{table}'")))?;
        match ing.drain() {
            Ok(stats) => Ok(stats),
            Err(e) => {
                self.streams.write().unwrap().entry(table.to_string()).or_insert(ing);
                Err(e)
            }
        }
    }

    /// Current table watermark of a streaming feature set.
    pub fn stream_watermark(&self, table: &str) -> Option<Timestamp> {
        self.streams.read().unwrap().get(table).and_then(|i| i.watermark())
    }

    /// Commit a streaming engine's consumer progress to the
    /// coordinator-owned checkpoint store (behind the engine's flush
    /// barrier). Subsequent polls reclaim the committed source-log
    /// prefix, clamped to the repair retention floor.
    pub fn checkpoint_stream(&self, table: &str) -> Result<()> {
        self.stream(table)?.checkpoint_to(&self.checkpoints);
        Ok(())
    }

    // ---- background maintenance ------------------------------------------

    /// Start the background TTL sweeper (ROADMAP follow-up): reclaims
    /// expired online entries and refreshes the freshness-violation
    /// gauge every `period`. Idempotent; the thread stops on
    /// [`FeatureStore::stop_ttl_sweeper`] or store drop.
    pub fn start_ttl_sweeper(&self, period: std::time::Duration) {
        let mut g = self.ttl_sweeper.write().unwrap();
        if g.is_none() {
            *g = Some(TtlSweeper::spawn(
                self.online.clone(),
                self.freshness.clone(),
                self.metrics.clone(),
                self.clock.clone(),
                period,
            ));
        }
    }

    pub fn stop_ttl_sweeper(&self) {
        self.ttl_sweeper.write().unwrap().take();
    }

    /// (Re)start the background offline compaction driver at `period`:
    /// size-tiered segment merges run on their own thread (woken by
    /// every delta spill, ticking at least every `period`), so batch
    /// materialization and the streaming dual-write keep
    /// constant-latency `merge` calls no matter how many segments a
    /// table has accumulated. A driver is already running after
    /// [`FeatureStore::open`] (100ms period); calling this replaces it,
    /// so the requested period always takes effect (the old thread is
    /// joined first). The thread stops on
    /// [`FeatureStore::stop_compaction`] or store drop.
    pub fn start_compaction(&self, period: std::time::Duration) {
        let mut g = self.compaction.write().unwrap();
        // Drop-then-spawn: dropping joins the old driver, so two
        // drivers never race the same store.
        g.take();
        *g = Some(CompactionDriver::spawn_observed(
            self.offline.clone(),
            period,
            Some(self.metrics.clone()),
            Some(self.tracer.clone()),
        ));
    }

    pub fn stop_compaction(&self) {
        self.compaction.write().unwrap().take();
    }

    // ---- retrieval ----------------------------------------------------------

    /// Online lookup by entity key from a consumer region, with RBAC
    /// (default read consistency: any replica).
    pub fn get_online(
        &self,
        principal: &Principal,
        table: &str,
        entity_key: &str,
        consumer_region: &str,
    ) -> Result<crate::geo::access::RoutedLookup> {
        self.get_online_with(
            principal,
            table,
            entity_key,
            consumer_region,
            &ReadConsistency::default(),
        )
    }

    /// Online lookup under an explicit [`ReadConsistency`] policy.
    pub fn get_online_with(
        &self,
        principal: &Principal,
        table: &str,
        entity_key: &str,
        consumer_region: &str,
        consistency: &ReadConsistency,
    ) -> Result<crate::geo::access::RoutedLookup> {
        let store = self.store_name()?;
        self.rbac.check(principal, &store, Action::ReadFeatures, self.clock.now())?;
        let Some(entity) = self.interner.lookup(entity_key) else {
            // Unknown entity: legitimate miss (vs not-materialized, which
            // the caller can distinguish via data-state).
            return Ok(crate::geo::access::RoutedLookup {
                record: None,
                mechanism: crate::geo::access::AccessMechanism::Local,
                latency_us: self.config.local_latency_us,
                staleness_secs: 0,
            });
        };
        self.serving.lookup(table, entity, consumer_region, self.clock.now(), consistency)
    }

    /// Batched online lookup: RBAC checked once, keys interned once,
    /// then one routed batch through the serving layer (one routing
    /// decision and one WAN round trip for the whole key set — the
    /// §3.1.4 hot-path amortization). Results are in input order;
    /// unknown entity keys are clean local misses. A thin wrapper over
    /// [`FeatureStore::get_online_many_mixed`] with a constant table, so
    /// single-table and mixed-table batches cannot diverge.
    pub fn get_online_many(
        &self,
        principal: &Principal,
        table: &str,
        entity_keys: &[&str],
        consumer_region: &str,
    ) -> Result<Vec<crate::geo::access::RoutedLookup>> {
        self.get_online_many_with(
            principal,
            table,
            entity_keys,
            consumer_region,
            &ReadConsistency::default(),
        )
    }

    /// Batched online lookup under an explicit [`ReadConsistency`]
    /// policy (one routing decision per table group).
    pub fn get_online_many_with(
        &self,
        principal: &Principal,
        table: &str,
        entity_keys: &[&str],
        consumer_region: &str,
        consistency: &ReadConsistency,
    ) -> Result<Vec<crate::geo::access::RoutedLookup>> {
        let requests: Vec<(&str, &str)> = entity_keys.iter().map(|&k| (table, k)).collect();
        self.get_online_many_mixed_with(principal, &requests, consumer_region, consistency)
    }

    /// Batched online lookup across **mixed tables** (ROADMAP follow-up:
    /// the micro-batcher already groups per table; this gives the
    /// coordinator endpoint the same shape). RBAC is checked once and
    /// keys are interned once; requests are grouped per table preserving
    /// first-seen order, each group is served by one routed batch (one
    /// WAN round trip per table), and results scatter back in input
    /// order. Unknown entity keys are clean local misses.
    pub fn get_online_many_mixed(
        &self,
        principal: &Principal,
        requests: &[(&str, &str)],
        consumer_region: &str,
    ) -> Result<Vec<crate::geo::access::RoutedLookup>> {
        self.get_online_many_mixed_with(
            principal,
            requests,
            consumer_region,
            &ReadConsistency::default(),
        )
    }

    /// Mixed-table batched lookup under an explicit [`ReadConsistency`]
    /// policy: one policy evaluation + one routed batch per table group.
    pub fn get_online_many_mixed_with(
        &self,
        principal: &Principal,
        requests: &[(&str, &str)],
        consumer_region: &str,
        consistency: &ReadConsistency,
    ) -> Result<Vec<crate::geo::access::RoutedLookup>> {
        use crate::geo::access::{AccessMechanism, RoutedLookup};
        let store = self.store_name()?;
        self.rbac.check(principal, &store, Action::ReadFeatures, self.clock.now())?;
        let now = self.clock.now();
        let mut out: Vec<RoutedLookup> = requests
            .iter()
            .map(|_| RoutedLookup {
                record: None,
                mechanism: AccessMechanism::Local,
                latency_us: self.config.local_latency_us,
                staleness_secs: 0,
            })
            .collect();
        // table → (input slot, entity) groups, in first-seen table order.
        let mut groups: Vec<(&str, Vec<(usize, EntityId)>)> = Vec::new();
        for (i, req) in requests.iter().enumerate() {
            let (table, key) = *req;
            let Some(entity) = self.interner.lookup(key) else { continue };
            match groups.iter_mut().find(|(t, _)| *t == table) {
                Some((_, items)) => items.push((i, entity)),
                None => groups.push((table, vec![(i, entity)])),
            }
        }
        for (table, items) in groups {
            let entities: Vec<EntityId> = items.iter().map(|&(_, e)| e).collect();
            // Tenant = the requesting principal: admission (when
            // configured) charges each table group against the caller's
            // and the table's budgets, shedding typed `Overloaded`.
            let batch = self.serving.lookup_batch_admitted(
                &principal.0,
                table,
                &entities,
                consumer_region,
                now,
                consistency,
            )?;
            for (&(i, _), record) in items.iter().zip(batch.records) {
                out[i] = RoutedLookup {
                    record,
                    mechanism: batch.mechanism,
                    latency_us: batch.latency_us,
                    staleness_secs: batch.staleness_secs,
                };
            }
        }
        Ok(out)
    }

    /// Offline PIT-correct training frame (§4.4), with RBAC + lineage
    /// recording for the requesting model.
    #[allow(clippy::too_many_arguments)]
    pub fn get_training_frame(
        &self,
        principal: &Principal,
        model: Option<crate::lineage::ModelId>,
        observations: &[(String, Timestamp)],
        features: &[FeatureRef],
        cfg: PitConfig,
        consumer_region: &str,
    ) -> Result<TrainingFrame> {
        let store = self.store_name()?;
        self.rbac.check(principal, &store, Action::ReadFeatures, self.clock.now())?;
        let obs: Vec<Observation> = observations
            .iter()
            .map(|(key, ts)| Observation { entity: self.interner.intern(key), ts: *ts })
            .collect();
        let specs: HashMap<String, FeatureSetSpec> = self.feature_set_specs();
        let trace = self.tracer.maybe_trace("training_frame");
        if let Some(t) = &trace {
            t.event("request", format!("obs={} features={}", obs.len(), features.len()));
        }
        // The engine streams the store's columnar segments and fans the
        // per-table joins out over the store's worker pool.
        let mut engine = OfflineQueryEngine::with_pool(self.offline.clone(), self.pool.clone());
        if let Some(t) = &trace {
            engine = engine.with_trace(t.clone());
        }
        let frame = engine.get_training_frame(&obs, features, &specs, cfg)?;
        if let Some(model) = model {
            self.lineage.record(model, features, consumer_region, self.clock.now());
        }
        self.metrics.inc(MetricKind::System, names::TRAINING_ROWS_SERVED, frame.len() as u64);
        if let Some(t) = &trace {
            t.event("result", format!("rows={}", frame.len()));
            t.finish();
        }
        Ok(frame)
    }

    // ---- observability (request tracing) -----------------------------------

    /// Drain the store's recent completed traces (oldest first). Sampled
    /// per [`OpenOptions::trace`]; empty when tracing is off.
    pub fn recent_traces(&self) -> Vec<Arc<CompletedTrace>> {
        self.tracer.recent()
    }

    /// Drain the slow-op log: every sampled request whose total duration
    /// crossed [`TraceConfig::slow_threshold_us`], full span tree
    /// included. Bounded ring — oldest entries are evicted, never
    /// blocked on.
    pub fn slow_ops(&self) -> Vec<Arc<CompletedTrace>> {
        self.tracer.slow_ops()
    }

    /// Data-state introspection (§4.3): is the window materialized?
    pub fn is_materialized(&self, table: &str, window: FeatureWindow) -> bool {
        self.scheduler.is_materialized(table, &window)
    }

    // ---- bootstrap (§4.5.5) --------------------------------------------------

    pub fn bootstrap_online_from_offline(
        &self,
        table: &str,
    ) -> Result<crate::offline_store::MergeStats> {
        let now = self.clock.now();
        // One gather feeds both the home merge (the §4.5.5 bootstrap,
        // same rule as `materialize::bootstrap_offline_to_online`) and
        // the fabric append — a direct coordinator write reaches
        // replicas through the same plane as every other merge, and the
        // replicated snapshot is exactly what was merged online.
        let latest = self.offline.latest_per_entity(table);
        let stats = self.online.merge(table, &latest, now);
        if let Some(f) = &self.fabric {
            // Transient durability hiccups are retried; a persistent
            // failure surfaces — the home merge above already landed,
            // but the caller must not assume replicas saw the snapshot.
            retry(&Backoff::default(), || f.append(table, &latest, now))?;
        }
        Ok(stats)
    }

    pub fn bootstrap_offline_from_online(&self, table: &str) -> crate::offline_store::MergeStats {
        crate::materialize::bootstrap_online_to_offline(
            &self.online,
            &self.offline,
            table,
            self.clock.now(),
        )
    }

    // ---- ops ------------------------------------------------------------------

    /// Persist offline segments + scheduler coverage for failover.
    pub fn checkpoint(&self, dir: PathBuf) -> Result<crate::geo::failover::RegionCheckpoint> {
        let fm = crate::geo::failover::FailoverManager::new(self.topology.clone());
        let cp = fm.checkpoint(
            self.config.home_region(),
            &self.scheduler,
            &self.offline,
            dir,
            self.clock.now(),
        )?;
        // Only after the segments are durable: advance the fabric's
        // truncation floor. Entries newer than this checkpoint stay in
        // the log even once applied everywhere — they are what failover
        // replays into a store restored from these segments.
        if let Some(f) = &self.fabric {
            f.record_checkpoint();
        }
        Ok(cp)
    }

    // ---- durable checkpoint / storage GC (manifest-addressed WAL) ----------

    /// Commit one durable-checkpoint manifest generation, atomically
    /// recording: a fresh compacted `.gfseg` snapshot per offline
    /// table, per-region replication cursors plus the fabric floor,
    /// every stream consumer's committed offsets, and the scheduler's
    /// materialization coverage. Recovery is this manifest + WAL tail
    /// replay — never a full segment dump.
    ///
    /// Crash-safe ordering: the floor is captured *without* touching
    /// the fabric, segments are written first (a crash strands
    /// unreferenced files — GC food, never recovery roots), the
    /// manifest commit is the atomic point, and only after it lands
    /// does the fabric's truncation floor advance. A failure anywhere
    /// leaves the previous checkpoint fully intact. Returns the
    /// committed generation.
    pub fn checkpoint_durable(&self) -> Result<u64> {
        let store = self
            .durable
            .as_ref()
            .ok_or_else(|| FsError::InvalidArg("store was opened without durability".into()))?;
        let now = self.clock.now();
        // Commit stream progress first so the manifest's consumer
        // checkpoints cover everything polled so far.
        for ing in self.streams.read().unwrap().values() {
            ing.checkpoint_to(&self.checkpoints);
        }
        // Captured, not recorded: if anything below fails, the fabric
        // keeps retaining from the old floor — nothing is reclaimed
        // against a checkpoint that never committed.
        let floor = self.fabric.as_ref().map(|f| f.token().offsets().to_vec());
        let mut segments = Vec::new();
        for name in self.offline.tables() {
            let segs = self.offline.snapshot(&name);
            let id = store.alloc_snapshot_id();
            let file = DurableStore::segment_file_name(id, &name);
            let path = store.dir().join(&file);
            let policy = Backoff::default();
            match segs.len() {
                0 => retry(&policy, || {
                    persist_segment_to(store.fs().as_ref(), &path, &Segment::from_unsorted(Vec::new()))
                })?,
                1 => retry(&policy, || persist_segment_to(store.fs().as_ref(), &path, &segs[0]))?,
                _ => {
                    let refs: Vec<&Segment> = segs.iter().map(|s| s.as_ref()).collect();
                    let merged = Segment::merge(&refs);
                    retry(&policy, || persist_segment_to(store.fs().as_ref(), &path, &merged))?;
                }
            }
            segments.push(SegmentRef { file, table: name });
        }
        let cursors = match &self.fabric {
            Some(f) => f.regions().into_iter().map(|r| { let c = f.cursors(&r); (r, c) }).collect(),
            None => Default::default(),
        };
        let gen = store.commit_checkpoint(now, |m| {
            m.segments = segments;
            m.cursors = cursors;
            m.checkpoint_floor = floor.clone();
            m.consumer_checkpoints = self.checkpoints.snapshot_entries();
            m.coverage = self.scheduler.checkpoint();
        })?;
        // The atomic point has passed: retention may now advance.
        if let (Some(f), Some(floor)) = (&self.fabric, floor) {
            f.set_checkpoint_floor(floor);
        }
        if let Some(gc) = &self.gc_driver {
            gc.ping(); // a pile of references just dropped
        }
        Ok(gen)
    }

    /// One storage-GC pass (mark or sweep — two passes reap an orphan;
    /// see `storage::gc`). No-op without durability.
    pub fn gc_storage(&self) -> Result<crate::storage::GcStats> {
        match &self.durable {
            Some(s) => s.gc(),
            None => Ok(crate::storage::GcStats::default()),
        }
    }

    /// Recovered-state audit document (what the manifest pins vs. what
    /// is on disk) — the torture harness uploads this as a CI artifact.
    pub fn storage_audit(&self) -> Result<Json> {
        self.durable
            .as_ref()
            .ok_or_else(|| FsError::InvalidArg("store was opened without durability".into()))?
            .audit()
    }

    /// Current freshness of a table.
    pub fn table_freshness(&self, table: &str) -> Option<crate::monitor::freshness::Freshness> {
        self.freshness.freshness(table, self.clock.now())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::governance::rbac::{Grant, Role};
    use crate::metadata::assets::SourceSpec;
    use crate::source::synthetic::SyntheticSource;
    use crate::types::time::{Granularity, DAY, HOUR};

    fn open_local() -> Arc<FeatureStore> {
        // No engine: unit tests here exercise coordination, not compute.
        let fs = FeatureStore::open(
            Config::default_local(),
            OpenOptions { with_engine: false, ..Default::default() },
        )
        .unwrap();
        fs.create_store("fs-test").unwrap();
        fs.create_entity(EntitySpec::new("customer", 1, &["customer_id"])).unwrap();
        fs.rbac.grant(Grant {
            principal: Principal("alice".into()),
            store: "fs-test".into(),
            role: Role::Admin,
            workspace: "ws".into(),
            workspace_region: "local".into(),
        });
        fs
    }

    fn register(fs: &FeatureStore, window_bins: usize) -> String {
        let spec = FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(5),
            Granularity(HOUR),
            window_bins,
        );
        let source = Arc::new(SyntheticSource::new(5, 30));
        fs.register_feature_set(spec, source, 0).unwrap()
    }

    #[test]
    fn end_to_end_tick_and_online_read() {
        let fs = open_local();
        let table = register(&fs, 4);
        fs.clock.set(2 * DAY);
        let outcomes = fs.materialize_tick(&table).unwrap();
        // Two daily intervals due; default max_bins_per_job coalesces
        // them into one job (§3.1.1).
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].window, FeatureWindow::new(0, 2 * DAY));
        assert!(fs.is_materialized(&table, FeatureWindow::new(0, 2 * DAY)));
        assert!(fs.offline.row_count(&table) > 0);

        let alice = Principal("alice".into());
        let got = fs.get_online(&alice, &table, "cust_00000", "local").unwrap();
        assert!(got.record.is_some());
        // Unknown key → clean miss.
        let miss = fs.get_online(&alice, &table, "ghost", "local").unwrap();
        assert!(miss.record.is_none());
        // RBAC enforced.
        assert!(fs.get_online(&Principal("mallory".into()), &table, "x", "local").is_err());
    }

    #[test]
    fn batched_online_read_matches_point_reads() {
        let fs = open_local();
        let table = register(&fs, 4);
        fs.clock.set(2 * DAY);
        fs.materialize_tick(&table).unwrap();
        let alice = Principal("alice".into());
        let keys = ["cust_00000", "ghost", "cust_00001", "cust_00002"];
        let batch = fs.get_online_many(&alice, &table, &keys, "local").unwrap();
        assert_eq!(batch.len(), keys.len());
        for (i, key) in keys.iter().enumerate() {
            let point = fs.get_online(&alice, &table, key, "local").unwrap();
            assert_eq!(
                batch[i].record.as_ref().map(|r| r.unique_key()),
                point.record.as_ref().map(|r| r.unique_key()),
                "key {key}"
            );
        }
        // RBAC enforced on the batched path too.
        assert!(fs
            .get_online_many(&Principal("mallory".into()), &table, &keys, "local")
            .is_err());
    }

    #[test]
    fn mixed_table_batch_matches_point_reads() {
        let fs = open_local();
        let table_a = register(&fs, 4);
        // Second feature set → second table, same entity space.
        let spec_b = FeatureSetSpec::rolling(
            "click",
            1,
            "customer",
            SourceSpec::synthetic(7),
            Granularity(HOUR),
            4,
        );
        let table_b = fs
            .register_feature_set(spec_b, Arc::new(SyntheticSource::new(7, 30)), 0)
            .unwrap();
        fs.clock.set(2 * DAY);
        fs.materialize_tick(&table_a).unwrap();
        fs.materialize_tick(&table_b).unwrap();

        let alice = Principal("alice".into());
        let requests: Vec<(&str, &str)> = vec![
            (table_a.as_str(), "cust_00000"),
            (table_b.as_str(), "cust_00001"),
            (table_a.as_str(), "ghost"),
            (table_b.as_str(), "cust_00000"),
            (table_a.as_str(), "cust_00002"),
        ];
        let batch = fs.get_online_many_mixed(&alice, &requests, "local").unwrap();
        assert_eq!(batch.len(), requests.len());
        for (i, (table, key)) in requests.iter().enumerate() {
            let point = fs.get_online(&alice, table, key, "local").unwrap();
            assert_eq!(
                batch[i].record.as_ref().map(|r| r.unique_key()),
                point.record.as_ref().map(|r| r.unique_key()),
                "{table}/{key}"
            );
        }
        // RBAC enforced on the mixed path too.
        assert!(fs
            .get_online_many_mixed(&Principal("mallory".into()), &requests, "local")
            .is_err());
        // Unknown table in a request is an error, like the per-table path.
        assert!(fs
            .get_online_many_mixed(&alice, &[("nope:1", "cust_00000")], "local")
            .is_err());
    }

    #[test]
    fn freshness_tracks_high_water() {
        let fs = open_local();
        let table = register(&fs, 2);
        fs.clock.set(DAY);
        fs.materialize_tick(&table).unwrap();
        let f = fs.table_freshness(&table).unwrap();
        assert_eq!(f.high_water, DAY);
        assert!(f.within_sla);
        fs.clock.set(4 * DAY); // fall behind
        assert!(!fs.table_freshness(&table).unwrap().within_sla);
    }

    #[test]
    fn backfill_then_training_frame() {
        let fs = open_local();
        let table = register(&fs, 4);
        fs.clock.set(3 * DAY);
        fs.backfill(&table, FeatureWindow::new(0, 2 * DAY)).unwrap();

        let alice = Principal("alice".into());
        let features = vec![FeatureRef::parse("txn:1:4h_sum").unwrap()];
        // Observations after the backfill's creation time (3d): PIT must
        // resolve to the latest record available at each observation.
        let observations: Vec<(String, Timestamp)> = (0..10)
            .map(|i| (format!("cust_{i:05}"), 3 * DAY + i as i64 * HOUR))
            .collect();
        let frame = fs
            .get_training_frame(
                &alice,
                Some(crate::lineage::ModelId { name: "churn".into(), version: 1 }),
                &observations,
                &features,
                PitConfig::default(),
                "local",
            )
            .unwrap();
        assert_eq!(frame.len(), 10);
        assert!(frame.fill_rate() > 0.0, "some observations must resolve");
        // Lineage recorded.
        assert_eq!(
            fs.lineage
                .features_of(&crate::lineage::ModelId { name: "churn".into(), version: 1 })
                .len(),
            1
        );
    }

    #[test]
    fn duplicate_store_and_missing_table_errors() {
        let fs = open_local();
        assert!(fs.create_store("fs-test").is_err());
        assert!(fs.materialize_tick("nope:1").is_err());
        assert!(matches!(
            fs.backfill("nope:1", FeatureWindow::new(0, DAY)),
            Err(FsError::NotFound(_))
        ));
    }

    #[test]
    fn streaming_materializes_into_both_stores() {
        let fs = open_local();
        let table = register(&fs, 2);
        fs.clock.set(2 * DAY);
        fs.start_stream(&table, StreamConfig::default()).unwrap();
        // Double-start is rejected; unknown tables too.
        assert!(fs.start_stream(&table, StreamConfig::default()).is_err());
        assert!(fs.start_stream("nope:1", StreamConfig::default()).is_err());

        let events = vec![
            StreamEvent::new(0, "cust_a", 30 * 60, 4.0),
            StreamEvent::new(1, "cust_a", HOUR + 300, 2.0),
            StreamEvent::new(2, "cust_b", HOUR + 400, 7.0),
            StreamEvent::new(3, "cust_a", 3 * HOUR, 0.0), // punctuation
            StreamEvent::new(4, "cust_b", 3 * HOUR, 0.0),
        ];
        fs.stream_ingest(&table, &events).unwrap();
        let stats = fs.drain_stream(&table).unwrap();
        assert!(stats.records_emitted > 0);
        assert_eq!(fs.stream_watermark(&table), Some(3 * HOUR));

        // Online point read through the full serving path (RBAC +
        // routing), event fresh within the poll — not a scheduler tick.
        let alice = Principal("alice".into());
        let got = fs.get_online(&alice, &table, "cust_a", "local").unwrap();
        let rec = got.record.expect("streamed record visible online");
        assert_eq!(rec.creation_ts, 2 * DAY);
        // Offline: same record version queryable via PIT.
        let frame = fs
            .get_training_frame(
                &alice,
                None,
                &[("cust_a".to_string(), 2 * DAY + HOUR), ("cust_b".to_string(), 2 * DAY + HOUR)],
                &[FeatureRef::parse("txn:1:2h_sum").unwrap()],
                PitConfig::default(),
                "local",
            )
            .unwrap();
        assert_eq!(frame.value(0, 0), Some(rec.values[0]));
        assert_eq!(frame.value(1, 0), Some(7.0));
        // Freshness follows the watermark, not the scheduler.
        let f = fs.table_freshness(&table).unwrap();
        assert_eq!(f.high_water, 3 * HOUR);
        assert!(fs.metrics.gauge("stream_watermark_lag_secs").is_some());

        // Stop is a drain barrier and detaches the engine.
        fs.stop_stream(&table).unwrap();
        assert!(fs.stream(&table).is_err());
        assert!(fs.poll_stream(&table).is_err());
    }

    #[test]
    fn ttl_sweeper_lifecycle() {
        let fs = open_local();
        let table = register(&fs, 2);
        fs.clock.set(DAY);
        fs.materialize_tick(&table).unwrap();
        assert!(!fs.online.is_empty());
        fs.start_ttl_sweeper(std::time::Duration::from_millis(2));
        fs.start_ttl_sweeper(std::time::Duration::from_millis(2)); // idempotent
        // Push the clock past the online TTL; the background thread must
        // reclaim without any manual evict call.
        fs.clock.set(DAY + 15 * DAY);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while !fs.online.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(fs.online.len(), 0, "sweeper must reclaim expired entries");
        assert!(fs.metrics.counter("ttl_evicted_total") > 0);
        fs.stop_ttl_sweeper();
    }

    #[test]
    fn compaction_driver_lifecycle() {
        let fs = open_local();
        // open() starts the driver by default — inline compaction is
        // gone, so the managed store must own the folding out of the box.
        assert!(fs.compaction.read().unwrap().is_some(), "open() must start the driver");
        fs.stop_compaction();
        assert!(fs.compaction.read().unwrap().is_none());
        fs.start_compaction(std::time::Duration::from_millis(1));
        fs.start_compaction(std::time::Duration::from_millis(1)); // restart: new period wins
        // Feed enough rows through the store's merge path to trip several
        // default-threshold spills; the background driver must fold the
        // tiers while every writer call stays on the constant-cost path.
        let rows: Vec<crate::types::FeatureRecord> = (0..6 * 1024)
            .map(|i| {
                crate::types::FeatureRecord::new(i as u64 % 97, i as i64, i as i64 + 5, vec![1.0])
            })
            .collect();
        for chunk in rows.chunks(512) {
            fs.offline.merge("t:1", chunk);
        }
        // 6 tier-0 spills at fanin 4 → the driver folds them below the
        // fanin without any writer-side compaction.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while fs.offline.storage_shape("t:1").0 >= 4 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let (segs, _) = fs.offline.storage_shape("t:1");
        assert!(segs < 4, "driver must fold tier 0, got {segs} segments");
        assert_eq!(fs.offline.row_count("t:1"), 6 * 1024);
        // Observability: the driver exports its work through the store's
        // metrics — a total counter, per-tier counters, and a backlog
        // gauge that has settled to zero once every tier is under-full.
        assert!(fs.metrics.counter("compaction_merges_total") > 0);
        assert!(fs.metrics.counter("compaction_merges_tier0") > 0);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while fs.metrics.gauge("compaction_backlog") != Some(0.0)
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(fs.metrics.gauge("compaction_backlog"), Some(0.0));
        fs.stop_compaction();
        assert!(fs.compaction.read().unwrap().is_none());
    }

    #[test]
    fn stream_log_truncates_through_coordinator_checkpoints() {
        use crate::types::time::HOUR;
        let fs = open_local();
        let table = register(&fs, 1);
        fs.clock.set(100 * HOUR);
        // A bounded repair horizon makes retention meaningful; the
        // engine is wired to the coordinator's CheckpointStore
        // automatically by start_stream.
        fs.start_stream(
            &table,
            StreamConfig { partitions: 1, retention_secs: 2 * HOUR, ..Default::default() },
        )
        .unwrap();
        let events: Vec<StreamEvent> =
            (0..20).map(|i| StreamEvent::new(i, "cust_a", i as i64 * HOUR + 30 * 60, 1.0)).collect();
        fs.stream_ingest(&table, &events).unwrap();
        fs.drain_stream(&table).unwrap();
        let ing = fs.stream(&table).unwrap();
        // Nothing committed yet → the poll retains everything.
        assert_eq!(ing.log().base_offset(0), 0);
        // Commit through the coordinator, then the next poll reclaims
        // the committed prefix below the repair floor — no caller-side
        // checkpoint-store plumbing involved.
        fs.checkpoint_stream(&table).unwrap();
        let s = fs.poll_stream(&table).unwrap();
        assert!(s.truncated > 0, "committed prefix must be reclaimed");
        assert!(ing.log().base_offset(0) > 0, "log base must advance");
        assert!(fs.checkpoint_stream("nope:1").is_err());
    }

    #[test]
    fn bootstrap_paths() {
        let fs = open_local();
        let table = register(&fs, 2);
        fs.clock.set(DAY);
        fs.materialize_tick(&table).unwrap();
        // Wipe online by bootstrapping a fresh store the other way:
        let fresh = FeatureStore::open(
            Config::default_local(),
            OpenOptions { with_engine: false, ..Default::default() },
        )
        .unwrap();
        // move offline data across (simulating late-enabled online store)
        let rows = fs.offline.scan(&table, FeatureWindow::new(0, 10 * DAY));
        fresh.offline.merge(&table, &rows);
        let stats = fresh.bootstrap_online_from_offline(&table).unwrap();
        assert!(stats.inserted > 0);
        let back = fresh.bootstrap_offline_from_online(&table);
        assert_eq!(back.inserted, 0); // already complete
    }
}
