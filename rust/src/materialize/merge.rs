//! Dual-store merge with eventual consistency (§4.5.2–§4.5.4).
//!
//! Every materialization job produces one table of records that must be
//! merged into **both** enabled sinks (Algorithm 2 per store).  Merges
//! can fail independently (the paper's §4.5.4 bullet: "Failed in one
//! merge but not the other (and retry succeeds)"); the merger retries
//! each sink independently and reports per-sink outcomes, so job-level
//! retries converge both stores to the same logical state.
//!
//! [`FaultInjector`] provides the controlled failure source used by the
//! consistency tests and benches (experiment E3).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::retry::{retry_with, RetryPolicy};
use crate::metadata::assets::MaterializationPolicy;
use crate::offline_store::{MergeStats, OfflineStore};
use crate::online_store::OnlineStore;
use crate::types::{FeatureRecord, FsError, Result, Timestamp};
use crate::util::rng::Rng;
use crate::util::Clock;

/// Injects transient store faults with a configured probability.
#[derive(Debug, Default)]
pub struct FaultInjector {
    pub offline_fail_p: f64,
    pub online_fail_p: f64,
    rng: Mutex<Option<Rng>>,
    pub injected: AtomicU64,
}

impl FaultInjector {
    pub fn none() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn with_rates(seed: u64, offline_fail_p: f64, online_fail_p: f64) -> Arc<Self> {
        Arc::new(FaultInjector {
            offline_fail_p,
            online_fail_p,
            rng: Mutex::new(Some(Rng::new(seed))),
            injected: AtomicU64::new(0),
        })
    }

    fn roll(&self, p: f64, what: &str) -> Result<()> {
        if p <= 0.0 {
            return Ok(());
        }
        let mut g = self.rng.lock().unwrap();
        if let Some(rng) = g.as_mut() {
            if rng.bool(p) {
                self.injected.fetch_add(1, Ordering::Relaxed);
                return Err(FsError::InjectedFault(format!("{what} merge failed")));
            }
        }
        Ok(())
    }
}

/// Per-job merge report (fed into monitoring + the scheduler).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MergeReport {
    pub offline: Option<MergeStats>,
    pub online: Option<MergeStats>,
    pub offline_attempts: u32,
    pub online_attempts: u32,
}

impl MergeReport {
    pub fn records_written(&self) -> u64 {
        self.offline.map(|s| s.inserted).unwrap_or(0)
            + self.online.map(|s| s.inserted).unwrap_or(0)
    }
}

/// Merges job output into both sinks per the feature set's policy.
pub struct DualStoreMerger {
    pub offline: Arc<OfflineStore>,
    pub online: Arc<OnlineStore>,
    pub faults: Arc<FaultInjector>,
    pub retry: RetryPolicy,
    clock: Clock,
}

impl DualStoreMerger {
    pub fn new(
        offline: Arc<OfflineStore>,
        online: Arc<OnlineStore>,
        faults: Arc<FaultInjector>,
        retry: RetryPolicy,
        clock: Clock,
    ) -> Self {
        DualStoreMerger { offline, online, faults, retry, clock }
    }

    /// Merge `records` into every enabled sink. Offline first, then
    /// online (§4.5.4's "sequence of processing the merge"); each sink
    /// retried independently. An error after retries fails the job —
    /// the job-level retry re-merges idempotently.
    pub fn merge(
        &self,
        table: &str,
        records: &[FeatureRecord],
        policy: &MaterializationPolicy,
        now: Timestamp,
    ) -> Result<MergeReport> {
        let mut report = MergeReport::default();
        if policy.offline_enabled {
            let out = retry_with(&self.retry, &self.clock, |_| {
                self.faults.roll(self.faults.offline_fail_p, "offline")?;
                Ok(self.offline.merge(table, records))
            })?;
            report.offline = Some(out.value);
            report.offline_attempts = out.attempts;
        }
        if policy.online_enabled {
            let out = retry_with(&self.retry, &self.clock, |_| {
                self.faults.roll(self.faults.online_fail_p, "online")?;
                Ok(self.online.merge(table, records, now))
            })?;
            report.online = Some(out.value);
            report.online_attempts = out.attempts;
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    fn merger(faults: Arc<FaultInjector>) -> DualStoreMerger {
        DualStoreMerger::new(
            Arc::new(OfflineStore::new()),
            Arc::new(OnlineStore::new(2)),
            faults,
            RetryPolicy { max_attempts: 10, ..Default::default() },
            Clock::fixed(0),
        )
    }

    #[test]
    fn merges_both_sinks() {
        let m = merger(FaultInjector::none());
        let recs = vec![rec(1, 100, 150, 1.0), rec(2, 100, 150, 2.0)];
        let rep = m.merge("t", &recs, &MaterializationPolicy::default(), 150).unwrap();
        assert_eq!(rep.offline.unwrap().inserted, 2);
        assert_eq!(rep.online.unwrap().inserted, 2);
        assert_eq!(m.offline.row_count("t"), 2);
        assert!(m.online.get("t", 1, 200).is_some());
    }

    #[test]
    fn respects_policy_flags() {
        let m = merger(FaultInjector::none());
        let recs = vec![rec(1, 100, 150, 1.0)];
        let policy = MaterializationPolicy { online_enabled: false, ..Default::default() };
        let rep = m.merge("t", &recs, &policy, 150).unwrap();
        assert!(rep.online.is_none());
        assert_eq!(m.offline.row_count("t"), 1);
        assert!(m.online.get("t", 1, 200).is_none());

        let policy = MaterializationPolicy { offline_enabled: false, ..Default::default() };
        let rep = m.merge("t2", &recs, &policy, 150).unwrap();
        assert!(rep.offline.is_none());
        assert!(m.online.get("t2", 1, 200).is_some());
    }

    #[test]
    fn remerge_after_compaction_still_dedupes() {
        // Offline compaction changes physical layout only: a job-level
        // re-merge of the same records through the dual-store path must
        // still be a pure no-op on the offline sink.
        let m = merger(FaultInjector::none());
        let recs: Vec<_> = (0..20).map(|i| rec(i, 100 + i as i64, 150 + i as i64, i as f32)).collect();
        m.merge("t", &recs, &MaterializationPolicy::default(), 150).unwrap();
        assert_eq!(m.offline.compact("t"), 1);
        let rep = m.merge("t", &recs, &MaterializationPolicy::default(), 160).unwrap();
        assert_eq!(rep.offline.unwrap(), MergeStats { inserted: 0, skipped: 20 });
        assert_eq!(m.offline.row_count("t"), 20);
    }

    #[test]
    fn transient_faults_retried_to_consistency() {
        let m = merger(FaultInjector::with_rates(7, 0.5, 0.5));
        let recs: Vec<_> = (0..50).map(|i| rec(i, 100, 150, i as f32)).collect();
        let rep = m.merge("t", &recs, &MaterializationPolicy::default(), 150).unwrap();
        // With p=0.5 and 10 attempts, success is (1 - 0.5^10) — the seed
        // used here succeeds; both stores hold the full set.
        assert_eq!(m.offline.row_count("t"), 50);
        assert_eq!(m.online.dump_table("t", 200).len(), 50);
        assert!(rep.offline_attempts >= 1 && rep.online_attempts >= 1);
        assert!(m.faults.injected.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn job_level_retry_converges_after_partial_failure() {
        // Force online to always fail → job errors after offline merged.
        let faults = FaultInjector::with_rates(3, 0.0, 1.0);
        let m = DualStoreMerger::new(
            Arc::new(OfflineStore::new()),
            Arc::new(OnlineStore::new(2)),
            faults,
            RetryPolicy { max_attempts: 2, ..Default::default() },
            Clock::fixed(0),
        );
        let recs = vec![rec(1, 100, 150, 1.0)];
        let err = m.merge("t", &recs, &MaterializationPolicy::default(), 150);
        assert!(err.is_err());
        // Offline got the data, online did not — the §4.5.4 divergence.
        assert_eq!(m.offline.row_count("t"), 1);
        assert!(m.online.get("t", 1, 200).is_none());

        // "Retry succeeds": heal the fault and re-merge the same records.
        let m2 = DualStoreMerger::new(
            m.offline.clone(),
            m.online.clone(),
            FaultInjector::none(),
            RetryPolicy::default(),
            Clock::fixed(0),
        );
        let rep = m2.merge("t", &recs, &MaterializationPolicy::default(), 160).unwrap();
        // Offline dedupes on the uniqueness key; online converges.
        assert_eq!(rep.offline.unwrap(), MergeStats { inserted: 0, skipped: 1 });
        assert_eq!(m.offline.row_count("t"), 1);
        assert!(m.online.get("t", 1, 200).is_some());
    }
}
