//! Feature materialization (§4.2, §4.3, §4.5).
//!
//! * [`calc`] — Algorithm 1: read the source window (feature window +
//!   lookback), bin, execute the planned transformation (AOT artifact or
//!   UDF), trim to the feature window, emit records.
//! * [`merge`] — Algorithm 2 applied to both sinks with retry and fault
//!   injection; eventual consistency between offline and online.
//! * [`bootstrap`] — §4.5.5: bring a late-enabled store up to parity.

pub mod bootstrap;
pub mod calc;
pub mod merge;

pub use bootstrap::{bootstrap_offline_to_online, bootstrap_online_to_offline};
pub use calc::Materializer;
pub use merge::{DualStoreMerger, FaultInjector, MergeReport};
