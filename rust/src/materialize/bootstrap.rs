//! Store bootstrap (§4.5.5): when a second sink is enabled later, bring
//! it to parity from the first — cheaper and more complete than
//! re-running backfill against sources that may no longer exist.

use crate::offline_store::{MergeStats, OfflineStore};
use crate::online_store::OnlineStore;
use crate::types::Timestamp;

/// Offline → online: for each entity take the record with
/// `max(tuple(event_ts, creation_ts))` and merge into the online store.
pub fn bootstrap_offline_to_online(
    offline: &OfflineStore,
    online: &OnlineStore,
    table: &str,
    now: Timestamp,
) -> MergeStats {
    let latest = offline.latest_per_entity(table);
    online.merge(table, &latest, now)
}

/// Online → offline: dump everything live in the online store into the
/// offline store (Alg 2's offline branch dedupes re-merges).
pub fn bootstrap_online_to_offline(
    online: &OnlineStore,
    offline: &OfflineStore,
    table: &str,
    now: Timestamp,
) -> MergeStats {
    let dump = online.dump_table(table, now);
    offline.merge(table, &dump)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::FeatureRecord;

    fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
        FeatureRecord::new(entity, event, created, vec![v])
    }

    #[test]
    fn offline_to_online_takes_latest_version() {
        let off = OfflineStore::new();
        let on = OnlineStore::new(2);
        off.merge(
            "t",
            &[
                rec(1, 10, 11, 0.0),
                rec(1, 20, 21, 1.0),
                rec(1, 20, 99, 2.0), // late recompute wins on creation_ts
                rec(2, 5, 6, 3.0),
            ],
        );
        let stats = bootstrap_offline_to_online(&off, &on, "t", 1_000);
        assert_eq!(stats.inserted, 2);
        let r1 = on.get("t", 1, 2_000).unwrap();
        assert_eq!(r1.version(), (20, 99));
        assert_eq!(r1.values[0], 2.0);
        assert_eq!(on.get("t", 2, 2_000).unwrap().values[0], 3.0);
    }

    #[test]
    fn online_to_offline_dumps_everything_live() {
        let off = OfflineStore::new();
        let on = OnlineStore::new(2);
        on.merge("t", &[rec(1, 10, 11, 1.0), rec(2, 20, 21, 2.0)], 21);
        let stats = bootstrap_online_to_offline(&on, &off, "t", 1_000);
        assert_eq!(stats.inserted, 2);
        assert_eq!(off.row_count("t"), 2);
    }

    #[test]
    fn bootstrap_is_idempotent() {
        let off = OfflineStore::new();
        let on = OnlineStore::new(2);
        off.merge("t", &[rec(1, 10, 11, 0.0)]);
        bootstrap_offline_to_online(&off, &on, "t", 100);
        let again = bootstrap_offline_to_online(&off, &on, "t", 200);
        assert_eq!(again.inserted, 0);
        assert_eq!(again.skipped, 1);

        bootstrap_online_to_offline(&on, &off, "t", 300);
        assert_eq!(off.row_count("t"), 1); // offline deduped
    }

    #[test]
    fn roundtrip_preserves_eq2_invariant() {
        // offline → online → offline: online state equals Eq. 2 of the
        // original offline contents; offline never loses rows.
        let off = OfflineStore::new();
        let on = OnlineStore::new(4);
        off.merge("t", &[rec(1, 10, 11, 0.0), rec(1, 30, 31, 1.0), rec(2, 20, 25, 2.0)]);
        bootstrap_offline_to_online(&off, &on, "t", 100);
        bootstrap_online_to_offline(&on, &off, "t", 200);
        assert_eq!(off.row_count("t"), 3);
        assert_eq!(on.get("t", 1, 999).unwrap().version(), (30, 31));
    }
}
