//! Algorithm 1 — feature calculation.
//!
//! ```text
//! source_window_start ← feature_window_start − source_lookback
//! df1 ← source.read(source_window)            (visible as of `as_of`)
//! df2 ← transform(df1)                        (AOT artifact or UDF)
//! feature_set_df ← df2 within feature window  (trim the halo)
//! ```
//!
//! The transform output schema contract (§4.2) — index columns,
//! timestamp column, all feature columns — maps here to: entity rows,
//! bin-end event timestamps, and the aggregation planes selected by the
//! feature set's DSL/UDF spec.

use std::sync::Arc;

use crate::dsl::{plan_transform, ExecutionPlan, PlanKind, UdfRegistry};
use crate::metadata::assets::FeatureSetSpec;
use crate::runtime::{ComputeHandle, RollPlanes};
use crate::source::{bin_events, SourceConnector};
use crate::types::{EntityInterner, FeatureRecord, FeatureWindow, FsError, Result, Timestamp};

/// The materialization compute engine: turns (spec, window, source) into
/// feature records. Stateless besides the shared interner and runtime.
pub struct Materializer {
    /// Compute service handle; `None` forces the in-process fallback
    /// everywhere (used by tests that don't want artifact dependencies).
    engine: Option<ComputeHandle>,
    udfs: UdfRegistry,
    interner: Arc<EntityInterner>,
}

impl Materializer {
    pub fn new(engine: Option<ComputeHandle>, interner: Arc<EntityInterner>) -> Self {
        Materializer { engine, udfs: UdfRegistry::new(), interner }
    }

    pub fn interner(&self) -> &Arc<EntityInterner> {
        &self.interner
    }

    pub fn udfs_mut(&mut self) -> &mut UdfRegistry {
        &mut self.udfs
    }

    /// Plan the spec's transformation against the loaded artifact set.
    pub fn plan(&self, spec: &FeatureSetSpec) -> Result<ExecutionPlan> {
        plan_transform(
            &spec.transform,
            spec.granularity,
            self.engine.as_ref().map(|e| e.manifest()),
        )
    }

    /// Plan `spec` and verify the plan can actually *execute* here: an
    /// artifact plan needs the AOT engine loaded, a UDF plan needs the
    /// named UDF registered. Start-time validation for callers (the
    /// streaming engine) that must not discover an unexecutable plan
    /// mid-stream — by the time `calculate` runs there, consumer offsets
    /// have already advanced, so a deterministic failure would become
    /// silent data loss instead of a clean start error.
    pub fn validate_executable(&self, spec: &FeatureSetSpec) -> Result<()> {
        let plan = self.plan(spec)?;
        match &plan.kind {
            PlanKind::Artifact(_) if self.engine.is_none() => Err(FsError::Runtime(
                "plan requires the AOT engine but none is loaded".into(),
            )),
            PlanKind::Artifact(_) => Ok(()),
            PlanKind::RustUdf => {
                let name = match &spec.transform {
                    crate::metadata::assets::TransformSpec::Udf(n) => n.as_str(),
                    _ => "rolling_recompute",
                };
                self.udfs.get(name).map(|_| ())
            }
        }
    }

    /// Run Algorithm 1 for one feature window.
    ///
    /// `as_of` is the processing-timeline read moment (drives source
    /// visibility of late data); `creation_ts` stamps the produced
    /// records (§4.5.1; normally = job completion time).
    pub fn calculate(
        &self,
        spec: &FeatureSetSpec,
        source: &dyn SourceConnector,
        feature_window: FeatureWindow,
        as_of: Timestamp,
        creation_ts: Timestamp,
    ) -> Result<Vec<FeatureRecord>> {
        let g = spec.granularity;
        if !g.aligned(feature_window.start) || !g.aligned(feature_window.end) {
            return Err(FsError::InvalidArg(format!(
                "feature window {feature_window} not aligned to granularity {}s",
                g.secs()
            )));
        }
        let plan = self.plan(spec)?;
        let window_bins = if plan.rolling.window_bins > 0 {
            plan.rolling.window_bins
        } else {
            spec.window_bins // UDF context: window comes from the spec
        };

        // 1. Source read over feature window + lookback halo.
        let halo_bins = window_bins - 1;
        let lookback = halo_bins as i64 * g.secs();
        let source_window = feature_window.source_window(lookback);
        let events = source.read(source_window, as_of)?;

        // 2. Bin into dense planes.
        let binned = bin_events(&events, &self.interner, feature_window, g, halo_bins);
        if binned.row_entities.is_empty() {
            return Ok(Vec::new()); // genuinely no data in the window
        }

        // 3. Execute the planned transformation.
        let rolled: RollPlanes = match (&plan.kind, &self.engine) {
            (PlanKind::Artifact(variant), Some(engine)) => {
                engine.rolling(*variant, &binned.planes, window_bins)?
            }
            (PlanKind::Artifact(_), None) => {
                return Err(FsError::Runtime(
                    "plan requires the AOT engine but none is loaded".into(),
                ))
            }
            (PlanKind::RustUdf, _) => {
                let name = match &spec.transform {
                    crate::metadata::assets::TransformSpec::Udf(n) => n.as_str(),
                    // DSL fallback path uses the reference recompute.
                    _ => "rolling_recompute",
                };
                self.udfs.get(name)?(&binned.planes, window_bins)?
            }
        };

        // 4. Emit records: one per (entity, non-empty output bin).
        let aggs = &plan.rolling.aggs;
        let n_bins = feature_window.bins(g) as usize;
        let mut out = Vec::new();
        for (row, &entity) in binned.row_entities.iter().enumerate() {
            for b in 0..n_bins {
                let full = rolled.feature_vec(row, b);
                if full[1] == 0.0 {
                    // Empty rolling window: no feature value for this bin
                    // (distinct from "not materialized" — §4.3).
                    continue;
                }
                let values: Vec<f32> =
                    aggs.iter().map(|a| full[a.output_index()]).collect();
                let event_ts = feature_window.start + (b as i64 + 1) * g.secs();
                out.push(FeatureRecord::new(entity, event_ts, creation_ts, values));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::assets::{SourceSpec, TransformSpec};
    use crate::source::synthetic::SyntheticSource;
    use crate::source::Event;
    use crate::types::time::{Granularity, HOUR};

    /// Fixed-event source for precise assertions.
    struct FixedSource(Vec<Event>);
    impl SourceConnector for FixedSource {
        fn read(&self, w: FeatureWindow, as_of: Timestamp) -> Result<Vec<Event>> {
            Ok(self
                .0
                .iter()
                .filter(|e| w.contains(e.ts) && e.ts <= as_of)
                .cloned()
                .collect())
        }
        fn describe(&self) -> String {
            "fixed".into()
        }
    }

    fn spec(window_bins: usize) -> FeatureSetSpec {
        FeatureSetSpec::rolling(
            "f",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity(HOUR),
            window_bins,
        )
    }

    fn mat() -> Materializer {
        Materializer::new(None, Arc::new(EntityInterner::new()))
    }

    #[test]
    fn alg1_window_math_and_values() {
        let m = mat();
        let s = spec(2);
        // Events: one in the halo hour (-1h) and one in hour 0.
        let src = FixedSource(vec![
            Event { key: "a".into(), ts: -HOUR + 5, value: 10.0 },
            Event { key: "a".into(), ts: 10, value: 4.0 },
        ]);
        let fw = FeatureWindow::new(0, 2 * HOUR);
        let recs = m.calculate(&s, &src, fw, i64::MAX, 3 * HOUR).unwrap();
        // bin0 ([-1h,1h) rolling): sum 14, cnt 2; bin1 ([0,2h)): sum 4.
        assert_eq!(recs.len(), 2);
        let r0 = &recs[0];
        assert_eq!(r0.event_ts, HOUR); // end of bin 0
        assert_eq!(r0.values[0], 14.0); // sum
        assert_eq!(r0.values[1], 2.0); // cnt
        assert_eq!(r0.values[2], 7.0); // mean
        assert_eq!(r0.values[3], 4.0); // min
        assert_eq!(r0.values[4], 10.0); // max
        let r1 = &recs[1];
        assert_eq!(r1.event_ts, 2 * HOUR);
        assert_eq!(r1.values[0], 4.0);
        assert_eq!(r1.creation_ts, 3 * HOUR);
    }

    #[test]
    fn empty_windows_emit_no_records() {
        let m = mat();
        let s = spec(2);
        let src = FixedSource(vec![Event { key: "a".into(), ts: 10, value: 1.0 }]);
        // Window [2h,4h): rolling windows cover [1h,3h) and [2h,4h) — the
        // event at 10s is outside both.
        let recs = m
            .calculate(&s, &src, FeatureWindow::new(2 * HOUR, 4 * HOUR), i64::MAX, 9 * HOUR)
            .unwrap();
        assert!(recs.is_empty());
    }

    #[test]
    fn as_of_hides_late_events() {
        let m = mat();
        let s = spec(1);
        let src = FixedSource(vec![Event { key: "a".into(), ts: HOUR + 30, value: 5.0 }]);
        let fw = FeatureWindow::new(HOUR, 2 * HOUR);
        // Read before the event is visible.
        let early = m.calculate(&s, &src, fw, HOUR, 2 * HOUR).unwrap();
        assert!(early.is_empty());
        // Read after: record appears with a later creation_ts (Fig 5's R3
        // late-arrival shape).
        let late = m.calculate(&s, &src, fw, i64::MAX, 9 * HOUR).unwrap();
        assert_eq!(late.len(), 1);
        assert_eq!(late[0].event_ts, 2 * HOUR);
        assert_eq!(late[0].creation_ts, 9 * HOUR);
    }

    #[test]
    fn unaligned_window_rejected() {
        let m = mat();
        let s = spec(2);
        let src = FixedSource(vec![]);
        assert!(m
            .calculate(&s, &src, FeatureWindow::new(5, HOUR), i64::MAX, HOUR)
            .is_err());
    }

    #[test]
    fn udf_transform_runs_blackbox() {
        let m = mat();
        let mut s = spec(3);
        s.transform = TransformSpec::Udf("rolling_recompute".into());
        let src = FixedSource(vec![Event { key: "a".into(), ts: 30, value: 2.0 }]);
        let recs = m
            .calculate(&s, &src, FeatureWindow::new(0, HOUR), i64::MAX, 2 * HOUR)
            .unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].values[0], 2.0);
    }

    #[test]
    fn unknown_udf_errors() {
        let m = mat();
        let mut s = spec(2);
        s.transform = TransformSpec::Udf("missing_udf".into());
        let src = FixedSource(vec![Event { key: "a".into(), ts: 30, value: 2.0 }]);
        assert!(m
            .calculate(&s, &src, FeatureWindow::new(0, HOUR), i64::MAX, HOUR)
            .is_err());
    }

    #[test]
    fn deterministic_over_synthetic_source() {
        let m = mat();
        let s = spec(4);
        let src = SyntheticSource::new(11, 20);
        let fw = FeatureWindow::new(0, 12 * HOUR);
        let a = m.calculate(&s, &src, fw, i64::MAX, 13 * HOUR).unwrap();
        let b = m.calculate(&s, &src, fw, i64::MAX, 13 * HOUR).unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn entity_ids_stable_across_windows() {
        let m = mat();
        let s = spec(1);
        let src = FixedSource(vec![
            Event { key: "x".into(), ts: 5, value: 1.0 },
            Event { key: "x".into(), ts: HOUR + 5, value: 2.0 },
        ]);
        let r1 = m.calculate(&s, &src, FeatureWindow::new(0, HOUR), i64::MAX, HOUR).unwrap();
        let r2 = m
            .calculate(&s, &src, FeatureWindow::new(HOUR, 2 * HOUR), i64::MAX, 2 * HOUR)
            .unwrap();
        assert_eq!(r1[0].entity, r2[0].entity);
    }
}
