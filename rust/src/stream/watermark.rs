//! Watermark tracking with bounded out-of-orderness (the streaming
//! plane's event-time progress clock).
//!
//! A watermark at `w` asserts "no more events with `ts < w` are
//! expected". With a bounded out-of-orderness contract of `L` seconds,
//! the watermark trails the largest observed event timestamp by `L`:
//!
//! ```text
//! watermark = max_seen_event_ts − allowed_lateness
//! ```
//!
//! Bins whose end falls at or below the watermark are *final* — the
//! pipeline materializes them and stamps `creation_ts`, which is
//! exactly what makes the streamed history PIT-consistent: a record is
//! only created once its input window can no longer grow, and an event
//! that *does* arrive below the watermark (violating the bound) is
//! routed through the late-repair path, producing a **new version**
//! with a later `creation_ts` — the same shape as the batch path's
//! late-data recompute (Fig 5's R3).
//!
//! The tracker also keeps a per-entity high-water mark. Partition-level
//! finalization must not stall on one quiet entity, so the *partition*
//! watermark derives from the global maximum; the per-entity marks
//! classify disorder (an event can be in-order for its entity yet late
//! for the partition, and vice versa) for monitoring and tests.

use std::collections::HashMap;

use crate::types::Timestamp;

/// Classification of one observed event against the tracker state
/// *before* the observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Observed {
    /// Event timestamp regressed vs the partition's max — out of order,
    /// but possibly still within the allowed-lateness bound.
    pub out_of_order: bool,
    /// Event timestamp fell below the watermark — the bounded
    /// out-of-orderness contract was violated (late event).
    pub beyond_lateness: bool,
    /// Event timestamp regressed vs its own entity's high-water mark.
    pub entity_regression: bool,
}

/// Per-partition watermark state.
#[derive(Debug)]
pub struct WatermarkTracker {
    allowed_lateness: i64,
    max_seen: Timestamp,
    per_key: HashMap<String, Timestamp>,
}

impl WatermarkTracker {
    pub fn new(allowed_lateness: i64) -> Self {
        assert!(allowed_lateness >= 0);
        WatermarkTracker { allowed_lateness, max_seen: Timestamp::MIN, per_key: HashMap::new() }
    }

    /// Largest event timestamp observed (`i64::MIN` before any event).
    pub fn max_seen(&self) -> Timestamp {
        self.max_seen
    }

    /// Current watermark (`i64::MIN` before any event).
    pub fn watermark(&self) -> Timestamp {
        if self.max_seen == Timestamp::MIN {
            Timestamp::MIN
        } else {
            self.max_seen - self.allowed_lateness
        }
    }

    /// Observe one event; returns its disorder classification and
    /// advances the marks. The watermark never regresses.
    pub fn observe(&mut self, key: &str, ts: Timestamp) -> Observed {
        let wm = self.watermark();
        let obs = Observed {
            out_of_order: self.max_seen != Timestamp::MIN && ts < self.max_seen,
            beyond_lateness: wm != Timestamp::MIN && ts < wm,
            entity_regression: self.per_key.get(key).is_some_and(|&hi| ts < hi),
        };
        if ts > self.max_seen {
            self.max_seen = ts;
        }
        match self.per_key.get_mut(key) {
            Some(hi) => {
                if ts > *hi {
                    *hi = ts;
                }
            }
            None => {
                self.per_key.insert(key.to_string(), ts);
            }
        }
        obs
    }

    /// Per-entity high-water mark.
    pub fn entity_high(&self, key: &str) -> Option<Timestamp> {
        self.per_key.get(key).copied()
    }

    pub fn tracked_entities(&self) -> usize {
        self.per_key.len()
    }
}

/// Table-level watermark: the minimum across partitions that have seen
/// data (a partition no entity routes to must not stall the table).
/// `None` until any partition has data.
pub fn min_watermark<'a>(trackers: impl IntoIterator<Item = &'a WatermarkTracker>) -> Option<Timestamp> {
    trackers
        .into_iter()
        .map(WatermarkTracker::watermark)
        .filter(|&w| w != Timestamp::MIN)
        .min()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watermark_trails_max_seen() {
        let mut t = WatermarkTracker::new(10);
        assert_eq!(t.watermark(), Timestamp::MIN);
        t.observe("a", 100);
        assert_eq!(t.watermark(), 90);
        t.observe("a", 150);
        assert_eq!(t.watermark(), 140);
        // Regression never lowers the watermark.
        t.observe("b", 120);
        assert_eq!(t.watermark(), 140);
        assert_eq!(t.max_seen(), 150);
    }

    #[test]
    fn classifies_disorder() {
        let mut t = WatermarkTracker::new(10);
        let first = t.observe("a", 100);
        assert_eq!(first, Observed { out_of_order: false, beyond_lateness: false, entity_regression: false });
        // Within the bound: out of order but not late.
        let within = t.observe("a", 95);
        assert!(within.out_of_order && !within.beyond_lateness && within.entity_regression);
        // Below the watermark (100 - 10 = 90): late.
        let late = t.observe("a", 85);
        assert!(late.beyond_lateness);
        // A different entity moving forward for itself can still be
        // partition-out-of-order.
        let b = t.observe("b", 99);
        assert!(b.out_of_order && !b.entity_regression);
        assert_eq!(t.entity_high("b"), Some(99));
        assert_eq!(t.entity_high("a"), Some(100));
        assert_eq!(t.tracked_entities(), 2);
    }

    #[test]
    fn zero_lateness_means_watermark_at_max() {
        let mut t = WatermarkTracker::new(0);
        t.observe("a", 50);
        assert_eq!(t.watermark(), 50);
        // Exactly at the watermark is not late (bins up to 50 are final,
        // and an event AT 50 belongs to the bin ending after 50).
        assert!(!t.observe("a", 50).beyond_lateness);
        assert!(t.observe("a", 49).beyond_lateness);
    }

    #[test]
    fn min_watermark_ignores_idle_partitions() {
        let mut a = WatermarkTracker::new(5);
        let b = WatermarkTracker::new(5); // idle — never observed
        let mut c = WatermarkTracker::new(5);
        assert_eq!(min_watermark([&a, &b, &c]), None);
        a.observe("x", 100);
        assert_eq!(min_watermark([&a, &b, &c]), Some(95));
        c.observe("y", 50);
        assert_eq!(min_watermark([&a, &b, &c]), Some(45));
    }

    #[test]
    fn prop_watermark_monotone_under_random_streams() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        for case in 0..20 {
            let lateness = rng.range(0, 500);
            let mut t = WatermarkTracker::new(lateness);
            let mut prev = Timestamp::MIN;
            for _ in 0..300 {
                let ts = rng.range(-1_000, 100_000);
                let key = format!("e{}", rng.below(6));
                let obs = t.observe(&key, ts);
                // Late ⟺ below the pre-observation watermark.
                assert_eq!(obs.beyond_lateness, prev != Timestamp::MIN && ts < prev, "case {case}");
                let wm = t.watermark();
                assert!(wm >= prev, "watermark regressed: {wm} < {prev}");
                assert!(wm == Timestamp::MIN || wm == t.max_seen() - lateness);
                prev = wm;
            }
        }
    }
}
