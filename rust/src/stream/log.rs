//! The partitioned, offset-addressed in-process event log — the
//! streaming plane's durable-broker analogue (Kafka/Event Hubs scaled
//! down to one process, the way `geo::topology` scales down Azure's
//! WAN).
//!
//! * [`PartitionedLog<T>`] is the generic substrate: N append-only
//!   partitions, each a dense offset-addressed run. Producers append,
//!   consumers poll `(offset, item)` pairs from a cursor they own — the
//!   log itself keeps **no** consumer state, so any number of readers
//!   (the ingestion pipeline, remote-region tailers, tests) can tail
//!   the same partition independently.
//! * [`EventLog`] specializes it for [`StreamEvent`]s and adds stable
//!   key→partition routing (same splitmix avalanche as the online
//!   store's shards), so all events of one entity land in one partition
//!   and per-entity order is preserved end to end.
//!
//! Items are retained until explicitly truncated: the log **is** the
//! replayable source of truth that makes consumer crash/resume
//! (`stream::consumer`) possible without snapshotting pipeline state.
//! [`PartitionedLog::truncate_below`] reclaims a prefix once every
//! consumer group's checkpoint (and the repair-retention floor) has
//! moved past it — offsets are stable across truncation: each partition
//! keeps a `base` offset, so offset arithmetic never shifts and a
//! cursor pointing below the base simply resumes at the base.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::storage::DurableLog;
use crate::types::{Result, Timestamp};

/// One raw stream event, as appended by a source.
///
/// `seq` is the **producer-assigned** unique identity of the event —
/// the dedupe key that turns at-least-once producer retries (the same
/// `seq` appended twice) into exactly-once pipeline effects. The log
/// never assigns identity: a broker cannot tell a retry from a new
/// event, only the producer can.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamEvent {
    pub seq: u64,
    /// Canonical entity key (index columns joined; see `EntityInterner`).
    pub key: String,
    /// Event timestamp on the event timeline.
    pub ts: Timestamp,
    /// Value column the transformation aggregates.
    pub value: f32,
}

impl StreamEvent {
    pub fn new(seq: u64, key: impl Into<String>, ts: Timestamp, value: f32) -> Self {
        StreamEvent { seq, key: key.into(), ts, value }
    }
}

/// One partition's state: retained items plus the offset of the first
/// retained item (`base` only grows, via truncation).
#[derive(Debug)]
struct Part<T> {
    base: u64,
    items: Vec<T>,
}

/// Generic N-partition append-only log with prefix truncation.
/// Partitions are independently locked; appends to different partitions
/// never contend.
#[derive(Debug)]
pub struct PartitionedLog<T> {
    parts: Vec<RwLock<Part<T>>>,
}

impl<T: Clone> PartitionedLog<T> {
    pub fn new(partitions: usize) -> Self {
        assert!(partitions > 0);
        PartitionedLog {
            parts: (0..partitions)
                .map(|_| RwLock::new(Part { base: 0, items: Vec::new() }))
                .collect(),
        }
    }

    pub fn partitions(&self) -> usize {
        self.parts.len()
    }

    /// Append one item; returns its offset within the partition.
    pub fn append(&self, partition: usize, item: T) -> u64 {
        let mut p = self.parts[partition].write().unwrap();
        p.items.push(item);
        p.base + (p.items.len() - 1) as u64
    }

    /// Exclusive end of the partition (next offset to be written).
    pub fn high_water(&self, partition: usize) -> u64 {
        let p = self.parts[partition].read().unwrap();
        p.base + p.items.len() as u64
    }

    /// Offset of the oldest retained item (0 until truncation).
    pub fn base_offset(&self, partition: usize) -> u64 {
        self.parts[partition].read().unwrap().base
    }

    /// Up to `max` items from `offset` (inclusive), with their offsets.
    /// An offset at/past the high-water mark yields an empty batch; an
    /// offset below the retained base resumes at the base (those items
    /// are gone — callers that need them had a checkpoint covering them).
    pub fn read_from(&self, partition: usize, offset: u64, max: usize) -> Vec<(u64, T)> {
        let p = self.parts[partition].read().unwrap();
        let lo = (offset.max(p.base) - p.base) as usize;
        let lo = lo.min(p.items.len());
        let hi = lo.saturating_add(max).min(p.items.len());
        p.items[lo..hi]
            .iter()
            .enumerate()
            .map(|(i, item)| (p.base + (lo + i) as u64, item.clone()))
            .collect()
    }

    /// Drop every item below `offset` (clamped to `[base, high_water]`).
    /// Returns the number of items reclaimed. Offsets of surviving items
    /// are unchanged.
    pub fn truncate_below(&self, partition: usize, offset: u64) -> u64 {
        let mut p = self.parts[partition].write().unwrap();
        let hw = p.base + p.items.len() as u64;
        let cut = offset.clamp(p.base, hw);
        let drop_n = (cut - p.base) as usize;
        if drop_n > 0 {
            p.items.drain(..drop_n);
            p.base = cut;
        }
        drop_n as u64
    }

    /// Overwrite one partition's retained state wholesale — the WAL
    /// recovery path (`storage::wal`) rebuilding the in-RAM mirror from
    /// replayed fragments. Not for steady-state use.
    #[doc(hidden)]
    pub fn restore_partition(&self, partition: usize, base: u64, items: Vec<T>) {
        let mut p = self.parts[partition].write().unwrap();
        p.base = base;
        p.items = items;
    }

    /// Retained items across all partitions (truncated items excluded).
    pub fn len(&self) -> usize {
        self.parts.iter().map(|p| p.read().unwrap().items.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// splitmix-style avalanche so textual keys with common prefixes spread
/// across partitions (mirrors `online_store::hash_of`; also the
/// replication fabric's table→partition router).
pub(crate) fn hash_key(key: &str) -> u64 {
    let mut x = 0xcbf29ce484222325u64;
    for b in key.as_bytes() {
        x ^= *b as u64;
        x = x.wrapping_mul(0x100000001b3);
    }
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// The log bytes behind an [`EventLog`]: plain RAM (the original
/// in-process broker) or a crash-safe WAL whose in-RAM mirror serves
/// every read (reads never touch disk; only appends pay for fsync).
enum Backing {
    Mem(PartitionedLog<StreamEvent>),
    Durable(Arc<DurableLog<StreamEvent>>),
}

impl std::fmt::Debug for Backing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backing::Mem(log) => write!(f, "Mem({} partitions)", log.partitions()),
            Backing::Durable(log) => write!(f, "Durable({:?})", log.name()),
        }
    }
}

/// The streaming source log: key-routed [`StreamEvent`] partitions plus
/// a convenience sequence generator for producers that do not manage
/// their own event identities.
#[derive(Debug)]
pub struct EventLog {
    backing: Backing,
    next_seq: AtomicU64,
}

impl EventLog {
    pub fn new(partitions: usize) -> Self {
        EventLog {
            backing: Backing::Mem(PartitionedLog::new(partitions)),
            next_seq: AtomicU64::new(0),
        }
    }

    /// Wrap a recovered durable log. The seq generator resumes past the
    /// largest replayed seq so log-assigned identities stay unique
    /// across restarts.
    pub fn durable(log: Arc<DurableLog<StreamEvent>>) -> Self {
        let mut next = 0;
        for p in 0..log.partitions() {
            for (_, ev) in log.mem().read_from(p, 0, usize::MAX) {
                next = next.max(ev.seq + 1);
            }
        }
        EventLog { backing: Backing::Durable(log), next_seq: AtomicU64::new(next) }
    }

    /// The read view (always RAM: the durable backing's mirror).
    fn view(&self) -> &PartitionedLog<StreamEvent> {
        match &self.backing {
            Backing::Mem(log) => log,
            Backing::Durable(log) => log.mem(),
        }
    }

    pub fn partitions(&self) -> usize {
        self.view().partitions()
    }

    /// The partition all events of `key` route to.
    pub fn partition_of(&self, key: &str) -> usize {
        (hash_key(key) % self.partitions() as u64) as usize
    }

    /// Append one event; returns `(partition, offset)`. On a durable
    /// backing the event is fsync-acked before this returns; an `Err`
    /// means the event is **not** acked (transient errors are safe to
    /// retry with the same `seq` — dedupe absorbs the replay).
    pub fn append(&self, event: StreamEvent) -> Result<(usize, u64)> {
        let p = self.partition_of(&event.key);
        let off = match &self.backing {
            Backing::Mem(log) => log.append(p, event),
            Backing::Durable(log) => log.append(p, event)?,
        };
        Ok((p, off))
    }

    /// Producer convenience: append with a log-assigned fresh `seq`
    /// (callers that replay/retry must assign their own seqs instead).
    pub fn emit(&self, key: &str, ts: Timestamp, value: f32) -> Result<(usize, u64)> {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        self.append(StreamEvent::new(seq, key, ts, value))
    }

    /// Append a batch of events, amortizing the durability ack: events
    /// are grouped by their routed partition (input order preserved
    /// within each partition — the only order the log defines) and each
    /// partition's run goes down as one [`DurableLog::append_many`], so
    /// a whole ingest call shares a handful of syncs instead of paying
    /// one per event. On `Err`, events of partitions already flushed
    /// are acked and the rest are not — the same at-least-once retry
    /// contract as per-event appends (seq dedupe absorbs replays).
    pub fn append_many(&self, events: &[StreamEvent]) -> Result<u64> {
        match &self.backing {
            Backing::Mem(log) => {
                for ev in events {
                    log.append(self.partition_of(&ev.key), ev.clone());
                }
            }
            Backing::Durable(log) => {
                let mut by_part: Vec<Vec<StreamEvent>> = vec![Vec::new(); self.partitions()];
                for ev in events {
                    by_part[self.partition_of(&ev.key)].push(ev.clone());
                }
                for (p, batch) in by_part.into_iter().enumerate() {
                    if !batch.is_empty() {
                        log.append_many(p, &batch)?;
                    }
                }
            }
        }
        Ok(events.len() as u64)
    }

    pub fn high_water(&self, partition: usize) -> u64 {
        self.view().high_water(partition)
    }

    pub fn base_offset(&self, partition: usize) -> u64 {
        self.view().base_offset(partition)
    }

    pub fn read_from(&self, partition: usize, offset: u64, max: usize) -> Vec<(u64, StreamEvent)> {
        self.view().read_from(partition, offset, max)
    }

    /// Reclaim events below `offset` (see [`PartitionedLog::truncate_below`]).
    /// On a durable backing this is RAM-only bookkeeping: the manifest
    /// floor advances lazily at the next checkpoint commit.
    pub fn truncate_below(&self, partition: usize, offset: u64) -> u64 {
        match &self.backing {
            Backing::Mem(log) => log.truncate_below(partition, offset),
            Backing::Durable(log) => log.truncate_below(partition, offset),
        }
    }

    pub fn len(&self) -> usize {
        self.view().len()
    }

    pub fn is_empty(&self) -> bool {
        self.view().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_and_read_offsets() {
        let log: PartitionedLog<u32> = PartitionedLog::new(2);
        assert_eq!(log.append(0, 10), 0);
        assert_eq!(log.append(0, 11), 1);
        assert_eq!(log.append(1, 20), 0);
        assert_eq!(log.high_water(0), 2);
        assert_eq!(log.read_from(0, 0, 10), vec![(0, 10), (1, 11)]);
        assert_eq!(log.read_from(0, 1, 10), vec![(1, 11)]);
        assert!(log.read_from(0, 2, 10).is_empty());
        assert_eq!(log.read_from(0, 0, 1), vec![(0, 10)]);
        assert_eq!(log.len(), 3);
    }

    #[test]
    fn independent_consumers_see_same_history() {
        let log: PartitionedLog<u32> = PartitionedLog::new(1);
        for i in 0..5 {
            log.append(0, i);
        }
        // Two cursors tail independently: no consumer state in the log.
        let a: Vec<_> = log.read_from(0, 0, usize::MAX);
        let b: Vec<_> = log.read_from(0, 3, usize::MAX);
        assert_eq!(a.len(), 5);
        assert_eq!(b, vec![(3, 3), (4, 4)]);
    }

    #[test]
    fn truncation_preserves_offsets_and_reclaims_memory() {
        let log: PartitionedLog<u32> = PartitionedLog::new(1);
        for i in 0..10 {
            log.append(0, i);
        }
        assert_eq!(log.truncate_below(0, 4), 4);
        assert_eq!(log.base_offset(0), 4);
        assert_eq!(log.len(), 6);
        // Surviving offsets are unchanged; reads below base resume at base.
        assert_eq!(log.read_from(0, 4, 2), vec![(4, 4), (5, 5)]);
        assert_eq!(log.read_from(0, 0, 3), vec![(4, 4), (5, 5), (6, 6)]);
        // Appends continue the offset sequence.
        assert_eq!(log.append(0, 99), 10);
        assert_eq!(log.high_water(0), 11);
        // Truncation is idempotent and clamps to the high-water mark.
        assert_eq!(log.truncate_below(0, 4), 0);
        assert_eq!(log.truncate_below(0, 1_000), 7);
        assert!(log.read_from(0, 0, 10).is_empty());
        assert_eq!(log.base_offset(0), 11);
        assert_eq!(log.append(0, 7), 11);
    }

    #[test]
    fn key_routing_is_stable_and_order_preserving() {
        let log = EventLog::new(4);
        for i in 0..20 {
            log.append(StreamEvent::new(i, "cust_7", i as i64, 0.0)).unwrap();
        }
        let p = log.partition_of("cust_7");
        // All in one partition, in append order.
        assert_eq!(log.high_water(p), 20);
        let seqs: Vec<u64> = log.read_from(p, 0, usize::MAX).iter().map(|(_, e)| e.seq).collect();
        assert_eq!(seqs, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn keys_spread_across_partitions() {
        let log = EventLog::new(8);
        for i in 0..256 {
            log.emit(&format!("cust_{i:05}"), 0, 0.0).unwrap();
        }
        let occupied = (0..8).filter(|&p| log.high_water(p) > 0).count();
        assert!(occupied >= 6, "keys should spread over partitions, got {occupied}/8");
        assert_eq!(log.len(), 256);
    }

    #[test]
    fn durable_backing_resumes_seqs_and_offsets_across_reopen() {
        use crate::storage::{DurableLogOptions, DurableStore, RealFs};
        use crate::testkit::TempDir;
        let dir = TempDir::new("eventlog-durable");
        let reopen = || {
            let store = DurableStore::open(Arc::new(RealFs), dir.path(), 0).unwrap();
            let wal = store
                .open_log::<StreamEvent>("stream/t", 2, DurableLogOptions::default())
                .unwrap();
            EventLog::durable(wal)
        };
        let log = reopen();
        log.emit("a", 1, 1.0).unwrap();
        log.emit("b", 2, 2.0).unwrap();
        let log2 = reopen();
        assert_eq!(log2.len(), 2, "replayed events are readable");
        let (_, _) = log2.emit("c", 3, 3.0).unwrap();
        let mut seqs: Vec<u64> = (0..2)
            .flat_map(|p| log2.read_from(p, 0, usize::MAX))
            .map(|(_, e)| e.seq)
            .collect();
        seqs.sort_unstable();
        assert_eq!(seqs, vec![0, 1, 2], "seq generator resumes past replayed ids");
    }

    #[test]
    fn emit_assigns_fresh_seqs() {
        let log = EventLog::new(2);
        log.emit("a", 1, 0.0).unwrap();
        log.emit("b", 2, 0.0).unwrap();
        let mut seqs: Vec<u64> = (0..2)
            .flat_map(|p| log.read_from(p, 0, usize::MAX))
            .map(|(_, e)| e.seq)
            .collect();
        seqs.sort_unstable();
        assert_eq!(seqs, vec![0, 1]);
    }
}
