//! Consumer-group offset checkpoints — the crash/resume substrate.
//!
//! The streaming engine's only durable state besides the sinks is a
//! per-`(group, table, partition)` checkpoint: the log offset up to
//! which effects are **fully applied to both sinks**, plus the
//! finalization boundary reached. Everything else (event buffer,
//! dedupe set, watermarks) is rebuilt by replaying the log below the
//! committed offset — the log is the source of truth, checkpoints are
//! cursors into it.
//!
//! Exactly-once contract: offsets are committed only *behind a flush
//! barrier* (the online write batcher is drained first, offline merges
//! are synchronous), so a crash can lose at most uncommitted work.
//! Replay from the last checkpoint re-delivers that work, and both
//! sinks absorb the redelivery idempotently — the offline store dedupes
//! on the `(entity, event_ts, creation_ts)` uniqueness key, the online
//! store's Eq. 2 merge is a monotone no-op for an already-applied
//! version. At-least-once delivery + idempotent dual-write =
//! exactly-once effects.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::types::{FsError, Result, Timestamp};
use crate::util::json::Json;

/// One partition's committed progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionCheckpoint {
    /// Next log offset to consume (all effects below it are durable in
    /// both sinks).
    pub offset: u64,
    /// Bin-finalization boundary at commit time (`None` = nothing
    /// finalized yet). Restoring it prevents re-emission of already
    /// final bins on resume.
    pub finalized_until: Option<Timestamp>,
    /// Newest creation stamp emitted by this partition (`None` = never
    /// emitted). Restoring it keeps the monotone-creation invariant
    /// across incarnations: without it, a post-resume repair of a
    /// committed bin could collide with the pre-crash version's
    /// `creation_ts` and be silently deduped away by both sinks.
    pub last_creation: Option<Timestamp>,
}

fn slot(group: &str, table: &str, partition: usize) -> String {
    format!("{group}\u{1f}{table}\u{1f}{partition}")
}

/// In-memory checkpoint store with JSON persistence (the ZooKeeper /
/// consumer-offsets-topic analogue, scaled down).
#[derive(Debug, Default)]
pub struct CheckpointStore {
    inner: Mutex<HashMap<String, PartitionCheckpoint>>,
    /// Known `(group, table)` consumers. A registered consumer that has
    /// not yet committed a partition **vetoes** truncation for it —
    /// otherwise a freshly-started group sharing an already-checkpointed
    /// log would silently lose the prefix another group's commits
    /// released. Registration is in-memory only (not persisted):
    /// consumers re-register when their engines re-attach after a
    /// restart, before any truncation can run.
    consumers: Mutex<std::collections::HashSet<(String, String)>>,
}

impl CheckpointStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare that `group` consumes `table` (idempotent). Engines call
    /// this before their first truncation opportunity so the retention
    /// bound can never run ahead of a consumer that exists but has not
    /// committed yet.
    pub fn register_consumer(&self, group: &str, table: &str) {
        self.consumers.lock().unwrap().insert((group.to_string(), table.to_string()));
    }

    /// Commit progress for one partition (overwrites prior commits).
    pub fn commit(&self, group: &str, table: &str, partition: usize, ck: PartitionCheckpoint) {
        self.inner.lock().unwrap().insert(slot(group, table, partition), ck);
    }

    pub fn get(&self, group: &str, table: &str, partition: usize) -> Option<PartitionCheckpoint> {
        self.inner.lock().unwrap().get(&slot(group, table, partition)).copied()
    }

    /// Minimum committed offset for `(table, partition)` across **all**
    /// consumer groups — the log-retention bound: everything below it
    /// has been durably applied by every group that committed this
    /// partition, so the log may truncate it (clamped further by the
    /// repair-retention floor; see `StreamIngestor::truncate_log`).
    /// `None` when no group has committed the partition yet, **or** when
    /// a [`CheckpointStore::register_consumer`]-declared consumer of the
    /// table has not committed it (retain everything for the laggard).
    /// Groups commit all partitions atomically in `checkpoint_to`, so a
    /// committed group cannot be silently skipped here by having
    /// committed only some partitions.
    pub fn min_committed_offset(&self, table: &str, partition: usize) -> Option<u64> {
        // Lock order: consumers, then inner (only this method takes both).
        let consumers = self.consumers.lock().unwrap();
        let g = self.inner.lock().unwrap();
        let mut min: Option<u64> = None;
        for (group, t) in consumers.iter() {
            if t != table {
                continue;
            }
            match g.get(&slot(group, table, partition)) {
                Some(ck) => min = Some(min.map_or(ck.offset, |m| m.min(ck.offset))),
                // Registered but uncommitted: veto truncation entirely.
                None => return None,
            }
        }
        // Commits from groups that never registered (e.g. loaded from a
        // persisted checkpoint file) still hold the bound down.
        for (key, ck) in g.iter() {
            let mut parts = key.split('\u{1f}');
            let _group = parts.next();
            if parts.next() != Some(table) {
                continue;
            }
            if parts.next().and_then(|p| p.parse::<usize>().ok()) != Some(partition) {
                continue;
            }
            min = Some(min.map_or(ck.offset, |m| m.min(ck.offset)));
        }
        min
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All checkpoints as the JSON document persisted to disk. Shared
    /// by [`CheckpointStore::persist`] and the durable-store manifest,
    /// which embeds the same document so one recovery path restores
    /// consumer cursors regardless of where they were recorded.
    pub fn snapshot_entries(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let entries: Vec<Json> = g
            .iter()
            .map(|(k, ck)| {
                Json::obj(vec![
                    ("slot", Json::str(k.as_str())),
                    ("offset", Json::num(ck.offset as f64)),
                    ("has_finalized", Json::Bool(ck.finalized_until.is_some())),
                    ("finalized_until", Json::num(ck.finalized_until.unwrap_or(0) as f64)),
                    ("has_creation", Json::Bool(ck.last_creation.is_some())),
                    ("last_creation", Json::num(ck.last_creation.unwrap_or(0) as f64)),
                ])
            })
            .collect();
        Json::obj(vec![("checkpoints", Json::Arr(entries))])
    }

    /// Merge entries produced by [`CheckpointStore::snapshot_entries`]
    /// into this store. Offsets only move forward: restoring an older
    /// snapshot over fresher in-memory progress must not rewind a
    /// cursor below work already applied.
    pub fn restore_entries(&self, doc: &Json) -> Result<()> {
        let entries = doc
            .get("checkpoints")
            .as_arr()
            .ok_or_else(|| FsError::Other("checkpoint document missing 'checkpoints'".into()))?;
        let mut g = self.inner.lock().unwrap();
        for e in entries {
            let key = e
                .get("slot")
                .as_str()
                .ok_or_else(|| FsError::Other("checkpoint entry missing 'slot'".into()))?
                .to_string();
            let offset = e
                .get("offset")
                .as_f64()
                .ok_or_else(|| FsError::Other("checkpoint entry missing 'offset'".into()))?
                as u64;
            let finalized_until = if e.get("has_finalized").as_bool().unwrap_or(false) {
                Some(e.get("finalized_until").as_i64().unwrap_or(0))
            } else {
                None
            };
            let last_creation = if e.get("has_creation").as_bool().unwrap_or(false) {
                Some(e.get("last_creation").as_i64().unwrap_or(0))
            } else {
                None
            };
            let ck = PartitionCheckpoint { offset, finalized_until, last_creation };
            match g.get(&key) {
                Some(existing) if existing.offset >= ck.offset => {}
                _ => {
                    g.insert(key, ck);
                }
            }
        }
        Ok(())
    }

    /// Persist all checkpoints to one JSON file (atomic replace: temp
    /// file + fsync + rename, so a crash never leaves a torn file).
    pub fn persist(&self, path: &Path) -> Result<()> {
        let doc = self.snapshot_entries();
        crate::storage::vfs::atomic_write(path, &[doc.to_string().as_bytes()])
    }

    /// Load a store persisted by [`CheckpointStore::persist`].
    pub fn load(path: &Path) -> Result<CheckpointStore> {
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text)
            .map_err(|e| FsError::Other(format!("bad checkpoint file {path:?}: {e}")))?;
        let store = CheckpointStore::new();
        store.restore_entries(&doc)?;
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::TempDir;

    fn ck(offset: u64, finalized_until: Option<Timestamp>, last_creation: Option<Timestamp>) -> PartitionCheckpoint {
        PartitionCheckpoint { offset, finalized_until, last_creation }
    }

    #[test]
    fn commit_overwrites_and_isolates_slots() {
        let s = CheckpointStore::new();
        s.commit("g", "t", 0, ck(5, None, None));
        s.commit("g", "t", 0, ck(9, Some(100), Some(140)));
        s.commit("g", "t", 1, ck(2, None, None));
        s.commit("g2", "t", 0, ck(7, None, None));
        assert_eq!(s.get("g", "t", 0).unwrap().offset, 9);
        assert_eq!(s.get("g", "t", 0).unwrap().finalized_until, Some(100));
        assert_eq!(s.get("g", "t", 0).unwrap().last_creation, Some(140));
        assert_eq!(s.get("g", "t", 1).unwrap().offset, 2);
        assert_eq!(s.get("g2", "t", 0).unwrap().offset, 7);
        assert!(s.get("g", "other", 0).is_none());
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn min_committed_offset_spans_groups() {
        let s = CheckpointStore::new();
        assert_eq!(s.min_committed_offset("t", 0), None);
        s.commit("g1", "t", 0, ck(9, None, None));
        assert_eq!(s.min_committed_offset("t", 0), Some(9));
        s.commit("g2", "t", 0, ck(4, None, None));
        assert_eq!(s.min_committed_offset("t", 0), Some(4));
        // Other partitions and tables do not interfere.
        s.commit("g1", "t", 1, ck(1, None, None));
        s.commit("g1", "other", 0, ck(0, None, None));
        assert_eq!(s.min_committed_offset("t", 0), Some(4));
        assert_eq!(s.min_committed_offset("t", 1), Some(1));
        assert_eq!(s.min_committed_offset("ghost", 0), None);
        // A lagging group holds the bound down even as others advance.
        s.commit("g1", "t", 0, ck(100, None, None));
        assert_eq!(s.min_committed_offset("t", 0), Some(4));
        // A registered-but-uncommitted consumer vetoes truncation: a
        // freshly-started group must not lose the prefix other groups
        // already released.
        s.register_consumer("g3", "t");
        assert_eq!(s.min_committed_offset("t", 0), None);
        s.commit("g3", "t", 0, ck(2, None, None));
        assert_eq!(s.min_committed_offset("t", 0), Some(2));
        // Registration is idempotent and table-scoped.
        s.register_consumer("g3", "t");
        s.register_consumer("g9", "elsewhere");
        assert_eq!(s.min_committed_offset("t", 0), Some(2));
    }

    #[test]
    fn persist_load_roundtrip() {
        let dir = TempDir::new("ckpt");
        let s = CheckpointStore::new();
        s.commit("g", "txn:1", 0, ck(123, Some(-7_200), Some(99)));
        s.commit("g", "txn:1", 3, ck(0, None, None));
        let path = dir.file("offsets.json");
        s.persist(&path).unwrap();

        let loaded = CheckpointStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded.get("g", "txn:1", 0), Some(ck(123, Some(-7_200), Some(99))));
        assert_eq!(loaded.get("g", "txn:1", 3), Some(ck(0, None, None)));
    }

    #[test]
    fn restore_entries_never_rewinds_offsets() {
        let s = CheckpointStore::new();
        s.commit("g", "t", 0, ck(10, None, None));
        let snap = s.snapshot_entries();
        // Progress past the snapshot, then restore the stale snapshot:
        // the fresher cursor must survive.
        s.commit("g", "t", 0, ck(20, Some(5), None));
        s.restore_entries(&snap).unwrap();
        assert_eq!(s.get("g", "t", 0).unwrap().offset, 20);
        // Slots absent in memory do land from the snapshot.
        let other = CheckpointStore::new();
        other.commit("g", "t", 1, ck(3, None, None));
        s.restore_entries(&other.snapshot_entries()).unwrap();
        assert_eq!(s.get("g", "t", 1).unwrap().offset, 3);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = TempDir::new("ckpt-bad");
        let path = dir.file("bad.json");
        std::fs::write(&path, "{not json").unwrap();
        assert!(CheckpointStore::load(&path).is_err());
        std::fs::write(&path, "{\"x\": 1}").unwrap();
        assert!(CheckpointStore::load(&path).is_err());
    }
}
