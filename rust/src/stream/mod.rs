//! Near-real-time streaming ingestion (the paper's streaming
//! materialization plane, §2.2/§4.3: feature sets materialize "from
//! both batch and streaming sources"; this is the streaming half the
//! scheduler-driven batch path was missing).
//!
//! # Architecture
//!
//! ```text
//!  sources ──append──▶ EventLog (N key-routed partitions, offset-addressed)
//!                          │ poll (per-partition cursor)
//!                          ▼
//!               PartitionPipeline × N          (stream::pipeline)
//!         buffer + seq-dedupe + watermark + late routing
//!                          │ EmitPlans (aligned windows)
//!                          ▼
//!              Materializer::calculate          (the batch Alg 1 —
//!                          │                     same DSL, same bins)
//!                          ▼ FeatureRecords (creation_ts = now)
//!              ┌───────────┼──────────────────┐
//!              ▼           ▼                  ▼
//!      OfflineStore   WriteBatcher      ReplicationFabric
//!      (sync merge,   (micro-batched    (store-wide record log;
//!       Alg 2 dedupe)  online merges)    replica regions tail it)
//! ```
//!
//! Per-partition work fans out over the shared [`ThreadPool`]; each
//! partition's state sits behind its own lock, and entities are
//! key-routed to exactly one partition, so rounds parallelize without
//! cross-partition coordination.
//!
//! Replication is **not** engine-local: emitted batches are appended to
//! the store-wide `geo::replication::ReplicationFabric` (the same
//! durable record log the batch scheduler appends to), whose background
//! `ReplicationDriver` delivers them to replica regions. The engine
//! keeps no per-region state and the replication log outlives engine
//! incarnations.
//!
//! # Exactly-once dual-write
//!
//! Every emitted record is merged into the offline store (append of a
//! new `(entity, event_ts, creation_ts)` version) and upserted online
//! (Eq. 2) **with identical timestamps**, so PIT training queries and
//! online serving see one history by construction. Delivery is
//! at-least-once (producer retries and post-crash replay re-deliver),
//! and both sinks are idempotent — offline dedupes on the uniqueness
//! key, online's Eq. 2 merge is a monotone no-op — so the *effect* is
//! exactly-once. Consumer offsets commit only behind a write-batcher
//! drain barrier ([`StreamIngestor::checkpoint_to`]), never ahead of
//! sink durability.
//!
//! # Consistency with the batch path
//!
//! Emission runs the **same** Algorithm-1 `calculate` the scheduler
//! uses, over the same granularity bins, gated by the watermark: a
//! record is created only when its input window can no longer grow
//! (bounded out-of-orderness), and bound-violating late events re-emit
//! the affected bins as new creation versions — the batch path's
//! late-data recompute shape. `tests/stream_consistency.rs` pins the
//! differential guarantee: streamed dual-write ≡ batch backfill (same
//! `TrainingFrame`, same online lookups) for arbitrary event sequences
//! with disorder and duplicate delivery.
//!
//! # Freshness
//!
//! The table watermark (min across active partitions) is the freshness
//! signal: each poll advances `monitor::freshness` to it and gauges
//! `stream_watermark_lag_secs`, so the SLA machinery treats "ripe but
//! unwatermarked" stream time exactly like unmaterialized batch time.
//! `stream_watermark_skew_secs` (max−min across partitions) exposes a
//! stuck partition before the table watermark visibly stalls.
//!
//! # Log retention
//!
//! When a [`CheckpointStore`] is attached (`StreamDeps::checkpoints`),
//! each poll truncates the source log below the minimum committed
//! offset across **all** consumer groups, clamped to the bin-aligned
//! repair retention floor — so log memory is bounded by consumer lag +
//! repair horizon instead of growing forever, while crash/resume and
//! late-repair replay keep working over the retained suffix.

pub mod consumer;
pub mod log;
pub mod pipeline;
pub mod watermark;

pub use consumer::{CheckpointStore, PartitionCheckpoint};
pub use log::{EventLog, PartitionedLog, StreamEvent};
pub use pipeline::{BufferSource, EmitPlan, PartitionPipeline, PartitionStats, PipelineConfig};
pub use watermark::{min_watermark, WatermarkTracker};

use std::sync::{Arc, Mutex, Weak};

use crate::exec::ThreadPool;
use crate::geo::replication::ReplicationFabric;
use crate::materialize::Materializer;
use crate::metadata::assets::FeatureSetSpec;
use crate::monitor::freshness::FreshnessTracker;
use crate::monitor::metrics::{MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::monitor::trace::Tracer;
use crate::offline_store::OfflineStore;
use crate::online_store::OnlineStore;
use crate::serving::batcher::{wall_us, BatcherConfig, FlushDriver, WriteBatcher};
use crate::types::{FsError, Result, Timestamp};
use crate::util::backoff::{retry, Backoff};
use crate::util::wake::Wake;
use crate::util::Clock;

/// Streaming engine configuration (per feature set).
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Event-log partitions (= max ingestion parallelism).
    pub partitions: usize,
    /// Bounded out-of-orderness: the watermark trails max event time by
    /// this many seconds.
    pub allowed_lateness_secs: i64,
    /// Repair horizon below the finalization boundary; `i64::MAX`
    /// retains everything (see `stream::pipeline`).
    pub retention_secs: i64,
    /// Emission windows are split into chunks of at most this many bins
    /// (the §3.1.1 context-aware partitioning unit, reused).
    pub max_bins_per_emit: i64,
    /// Online write stage batching.
    pub writer: BatcherConfig,
    /// Spawn the background write-flush driver (wall-clock
    /// `max_wait_us`). When false the poll loop flushes inline —
    /// deterministic, for tests and simulated time.
    pub writer_driver: bool,
    /// Queued-record bound above which a poll flushes inline even with
    /// a driver attached (backpressure when the dual-write stage falls
    /// behind).
    pub max_pending_online: usize,
    /// Admission bound on the source log: [`StreamIngestor::try_ingest`]
    /// sheds (typed `Overloaded`) when the unconsumed backlog would
    /// exceed this many events. `usize::MAX` = never shed (the plain
    /// `ingest` path is always unbounded).
    pub max_backlog_events: usize,
    /// Consumer-group name for checkpoints.
    pub group: String,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            partitions: 4,
            allowed_lateness_secs: 0,
            retention_secs: i64::MAX,
            max_bins_per_emit: 256,
            writer: BatcherConfig::default(),
            writer_driver: false,
            max_pending_online: 4_096,
            max_backlog_events: usize::MAX,
            group: "default".into(),
        }
    }
}

/// Everything the engine needs from the surrounding store.
pub struct StreamDeps {
    pub materializer: Arc<Materializer>,
    pub offline: Arc<OfflineStore>,
    pub online: Arc<OnlineStore>,
    pub freshness: Arc<FreshnessTracker>,
    pub metrics: Arc<MetricsRegistry>,
    pub clock: Clock,
    /// Fan per-partition rounds out here (None = sequential).
    pub pool: Option<Arc<ThreadPool>>,
    /// The store-wide replication fabric: every emitted batch is
    /// appended so replica regions receive streaming writes through the
    /// same plane as batch writes. `None` = no replication.
    pub fabric: Option<Arc<ReplicationFabric>>,
    /// Consumer-group checkpoint store consulted by `poll` for log
    /// retention: events below the minimum committed offset across
    /// **all** groups (clamped to the bin-aligned repair retention
    /// floor) are truncated from the source log. `None` = retain
    /// everything (the pre-retention behavior; also what keeps ad-hoc
    /// test engines trivially replayable).
    pub checkpoints: Option<Arc<CheckpointStore>>,
    /// Request tracer: sampled `poll_partition` rounds record their
    /// absorb/materialize/dual-write breakdown. `None` = untraced.
    pub tracer: Option<Arc<Tracer>>,
}

/// One poll round's aggregate outcome.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StreamStats {
    /// Log entries consumed this round.
    pub consumed: u64,
    /// Records dual-written (offline merge + online enqueue).
    pub records_emitted: u64,
    /// Aggregated pipeline counters (since engine start).
    pub pipeline: PartitionStats,
    /// Records still queued in the online write stage.
    pub pending_online: u64,
    /// Table watermark after the round (None until any partition has
    /// data).
    pub watermark: Option<Timestamp>,
    /// Max−min watermark across partitions with data (0 with ≤ 1 active
    /// partition): the stuck-partition signal — one stalled partition
    /// drags the table watermark (the min) while healthy partitions run
    /// ahead (the max), so skew grows long before freshness trips.
    pub watermark_skew_secs: i64,
    /// Log entries reclaimed by retention this round.
    pub truncated: u64,
}

/// Per-partition consumer + pipeline state.
struct PartState {
    next_offset: u64,
    pipeline: PartitionPipeline,
    /// Creation stamp of the newest emission from this partition.
    /// Emissions stamp `max(clock.now(), last_creation + 1)`: two
    /// materializations of the same bin (original + late repair) must
    /// never share a creation_ts, or the offline uniqueness key would
    /// silently drop the recompute and Eq. 2 could not order it online.
    /// Trade-off: when a partition emits more than once per clock
    /// second, creation stamps run ahead of the clock by one second per
    /// emitting poll (bounded by the polls-per-second × stagnant-clock
    /// window); records stamped ahead are PIT-invisible until the clock
    /// catches up. Second-granularity timestamps make this unavoidable —
    /// a finer `creation_ts` resolution is the ROADMAP follow-up.
    last_creation: Timestamp,
}

struct PartRound {
    consumed: u64,
    records: u64,
    stats: PartitionStats,
    watermark: Timestamp,
}

/// Fold one partition watermark into a table minimum, ignoring
/// partitions that have never seen data (`i64::MIN`) — the single
/// definition behind [`StreamIngestor::watermark`] and `poll`'s
/// per-round aggregate (mirrors [`min_watermark`] for owned values).
fn fold_min_wm(acc: Option<Timestamp>, w: Timestamp) -> Option<Timestamp> {
    if w == Timestamp::MIN {
        acc
    } else {
        Some(acc.map_or(w, |cur| cur.min(w)))
    }
}

/// The near-real-time ingestion engine for one feature set.
pub struct StreamIngestor {
    /// Self-handle for fanning partition tasks out over the pool
    /// (tasks need an owning `Arc`; set via `Arc::new_cyclic`).
    me: Weak<StreamIngestor>,
    table: String,
    spec: FeatureSetSpec,
    cfg: StreamConfig,
    log: Arc<EventLog>,
    parts: Vec<Mutex<PartState>>,
    writer: Arc<WriteBatcher>,
    deps: StreamDeps,
    /// Pinged by every poll that consumed events — the backlog-drain
    /// signal [`StreamIngestor::ingest_blocking`] parks on.
    drained: Wake,
    _writer_driver: Option<FlushDriver>,
}

impl StreamIngestor {
    /// Build an engine for `spec` with a fresh event log. Validates the
    /// spec and its transform plan up front so a mis-registered feature
    /// set fails at start, not mid-stream.
    pub fn new(spec: FeatureSetSpec, cfg: StreamConfig, deps: StreamDeps) -> Result<Arc<StreamIngestor>> {
        let log = Arc::new(EventLog::new(cfg.partitions.max(1)));
        Self::with_log(spec, cfg, deps, log)
    }

    /// Build an engine over an **existing** event log — the crash/resume
    /// path: the log is the durable broker analogue and outlives engine
    /// incarnations; a restarted process re-attaches here and then
    /// [`StreamIngestor::restore_from`] its checkpoints.
    pub fn with_log(
        spec: FeatureSetSpec,
        cfg: StreamConfig,
        deps: StreamDeps,
        log: Arc<EventLog>,
    ) -> Result<Arc<StreamIngestor>> {
        if cfg.partitions == 0 {
            return Err(FsError::InvalidArg("stream partitions must be > 0".into()));
        }
        if log.partitions() != cfg.partitions {
            return Err(FsError::InvalidArg(format!(
                "log has {} partitions, config says {}",
                log.partitions(),
                cfg.partitions
            )));
        }
        if cfg.max_bins_per_emit <= 0 {
            return Err(FsError::InvalidArg("max_bins_per_emit must be > 0".into()));
        }
        if cfg.allowed_lateness_secs < 0 || cfg.retention_secs < 0 {
            return Err(FsError::InvalidArg("lateness/retention must be >= 0".into()));
        }
        spec.validate()?;
        // Executability (not just plan-ability) is checked up front: a
        // deterministic calculate failure mid-stream would strand
        // already-consumed offsets (see Materializer::validate_executable).
        deps.materializer.validate_executable(&spec)?;
        let table = spec.reference();
        // Declare this engine's consumer group before any truncation can
        // run: an uncommitted registered group vetoes log retention, so
        // a second engine attaching to a shared, already-checkpointed
        // log cannot lose the prefix the first engine's commits released.
        if let Some(ck) = &deps.checkpoints {
            ck.register_consumer(&cfg.group, &table);
        }
        let pcfg = PipelineConfig {
            granularity: spec.granularity,
            window_bins: spec.window_bins.max(1),
            allowed_lateness_secs: cfg.allowed_lateness_secs,
            retention_secs: cfg.retention_secs,
        };
        let parts = (0..cfg.partitions)
            .map(|_| {
                Mutex::new(PartState {
                    next_offset: 0,
                    pipeline: PartitionPipeline::new(pcfg),
                    last_creation: Timestamp::MIN,
                })
            })
            .collect();
        let writer = Arc::new(WriteBatcher::new(cfg.writer));
        let writer_driver = cfg
            .writer_driver
            .then(|| writer.spawn_driver(deps.online.clone(), deps.clock.clone()));
        Ok(Arc::new_cyclic(|me| StreamIngestor {
            me: me.clone(),
            log,
            table,
            spec,
            cfg,
            parts,
            writer,
            deps,
            drained: Wake::default(),
            _writer_driver: writer_driver,
        }))
    }

    pub fn table(&self) -> &str {
        &self.table
    }

    /// The entity interner records intern through (shared with the
    /// materializer; needed to resolve store-local entity ids back to
    /// keys).
    pub fn interner(&self) -> Arc<crate::types::EntityInterner> {
        self.deps.materializer.interner().clone()
    }

    /// The source event log (external producers append here too).
    pub fn log(&self) -> &Arc<EventLog> {
        &self.log
    }

    /// Append events (key-routed to partitions). Returns the count.
    /// Never sheds — producers that must not lose events use this and
    /// absorb the backlog; front ends facing untrusted producers use
    /// [`Self::try_ingest`] or [`Self::ingest_blocking`]. The batch
    /// goes down via [`EventLog::append_many`], so on a durable log one
    /// ingest call shares a sync per touched partition instead of
    /// paying one per event. An `Err` means at least the failing
    /// event's partition run is **not** acked; re-ingesting the same
    /// batch is safe — seq dedupe absorbs the already-acked part.
    pub fn ingest(&self, events: &[StreamEvent]) -> Result<u64> {
        self.log.append_many(events)
    }

    /// Admission-controlled ingest: sheds the whole batch with a typed
    /// `Overloaded` error when the unconsumed backlog would exceed
    /// `cfg.max_backlog_events` — bounded ingest memory instead of an
    /// ever-deeper log while the poll loop is saturated. Shed events are
    /// counted in the `stream_shed_events` metric; admitted batches
    /// behave exactly like [`Self::ingest`].
    pub fn try_ingest(&self, events: &[StreamEvent]) -> Result<u64> {
        let backlog = self.backlog();
        if backlog.saturating_add(events.len() as u64) > self.cfg.max_backlog_events as u64 {
            self.deps.metrics.inc(
                MetricKind::System,
                names::STREAM_SHED_EVENTS,
                events.len() as u64,
            );
            return Err(FsError::Overloaded {
                resource: format!("stream '{}'", self.table),
                reason: format!(
                    "backlog {backlog} + {} > {}",
                    events.len(),
                    self.cfg.max_backlog_events
                ),
            });
        }
        self.ingest(events)
    }

    /// Backpressuring ingest: where [`Self::try_ingest`] sheds on a full
    /// backlog, this **waits** for the poll loop to drain headroom —
    /// parked on a condvar pinged by every consuming poll, so producers
    /// slow to consumer speed instead of failing or spinning. Gives up
    /// with a typed `Overloaded` once `timeout` elapses without enough
    /// headroom (deadline-capped: a stalled poll loop cannot wedge
    /// producers forever).
    pub fn ingest_blocking(
        &self,
        events: &[StreamEvent],
        timeout: std::time::Duration,
    ) -> Result<u64> {
        let cap = self.cfg.max_backlog_events as u64;
        let deadline = std::time::Instant::now() + timeout;
        let mut seen = 0u64;
        loop {
            let backlog = self.backlog();
            if backlog.saturating_add(events.len() as u64) <= cap {
                return self.ingest(events);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                self.deps.metrics.inc(
                    MetricKind::System,
                    names::STREAM_SHED_EVENTS,
                    events.len() as u64,
                );
                return Err(FsError::Overloaded {
                    resource: format!("stream '{}'", self.table),
                    reason: format!(
                        "backlog {backlog} + {} > {cap} after waiting {timeout:?}",
                        events.len()
                    ),
                });
            }
            seen = self.drained.wait(seen, deadline - now);
        }
    }

    /// Ingested-but-unconsumed events across partitions (the admission
    /// signal `try_ingest` checks).
    pub fn backlog(&self) -> u64 {
        let mut n = 0u64;
        for (p, st) in self.parts.iter().enumerate() {
            let next = st.lock().unwrap().next_offset;
            n += self.log.high_water(p).saturating_sub(next);
        }
        n
    }

    /// Table watermark: min across partitions that have seen data.
    pub fn watermark(&self) -> Option<Timestamp> {
        let mut wm: Option<Timestamp> = None;
        for p in &self.parts {
            wm = fold_min_wm(wm, p.lock().unwrap().pipeline.watermark());
        }
        wm
    }

    /// Records queued in the online write stage (backpressure signal).
    pub fn pending_online(&self) -> usize {
        self.writer.pending()
    }

    /// One partition's round: poll new log entries, absorb, execute the
    /// pipeline's emit/repair plans through Algorithm 1, dual-write.
    fn poll_partition(&self, p: usize) -> Result<PartRound> {
        let trace =
            self.deps.tracer.as_ref().and_then(|t| t.maybe_trace("stream_poll_partition"));
        let mut st = self.parts[p].lock().unwrap();
        let entries = self.log.read_from(p, st.next_offset, usize::MAX);
        for (off, ev) in &entries {
            st.pipeline.absorb(ev);
            st.next_offset = off + 1;
        }
        let plans = st.pipeline.plans();
        if let Some(t) = &trace {
            t.event(
                "absorb",
                format!("partition={p} entries={} plans={}", entries.len(), plans.len()),
            );
        }
        let proc_now = self.deps.clock.now();
        // Monotone per-partition creation stamp: a repair in the same
        // logical second as the original emission must still produce a
        // distinguishable (and Eq. 2-orderable) version.
        let now = proc_now.max(st.last_creation.saturating_add(1));
        if !plans.is_empty() {
            st.last_creation = now;
        }
        let mut records_out = 0u64;
        let mat_span = trace.as_ref().map(|t| t.span("materialize"));
        for plan in plans {
            for window in plan.window.split(self.spec.granularity, self.cfg.max_bins_per_emit) {
                let source = BufferSource::new(st.pipeline.buffer(), plan.keys.as_deref());
                // as_of = MAX: watermark gating already decided visibility;
                // creation_ts = now stamps availability (§4.5.1).
                let records =
                    self.deps.materializer.calculate(&self.spec, &source, window, i64::MAX, now)?;
                if records.is_empty() {
                    continue;
                }
                records_out += records.len() as u64;
                let shared: Arc<[crate::types::FeatureRecord]> = records.into();
                // Dual-write: offline synchronously (Alg 2 idempotent
                // append), online through the micro-batched write stage,
                // replicas via the store-wide replication fabric — all
                // three share one allocation and identical timestamps.
                self.deps.offline.merge(&self.table, &shared);
                self.writer.push(&self.table, shared.clone(), wall_us());
                if let Some(fabric) = &self.deps.fabric {
                    // appended_at is *processing* time (the lag-visibility
                    // rule is defined against it), not the bumped
                    // creation stamp — a bumped stamp would push
                    // visibility past the lag and, because fabric tailing
                    // is prefix-ordered, block later honest entries too.
                    // Transient durable-append errors retry with bounded
                    // backoff (replica merges are idempotent, so a
                    // duplicate replay of a half-acked attempt is safe);
                    // persistent failure aborts the round.
                    retry(&Backoff::default(), || {
                        fabric.append_shared(&self.table, shared.clone(), proc_now)
                    })?;
                }
            }
        }
        if let Some(g) = &mat_span {
            g.note(format!("records={records_out}"));
        }
        drop(mat_span);
        if let Some(t) = &trace {
            t.finish();
        }
        Ok(PartRound {
            consumed: entries.len() as u64,
            records: records_out,
            stats: st.pipeline.stats,
            watermark: st.pipeline.watermark(),
        })
    }

    /// Process everything currently in the log: per-partition rounds
    /// (fanned out over the pool when available), then flush/backpressure
    /// the online write stage and advance the freshness signal.
    pub fn poll(&self) -> Result<StreamStats> {
        let n = self.parts.len();
        let rounds: Vec<Result<PartRound>> = match (&self.deps.pool, self.me.upgrade()) {
            (Some(pool), Some(me)) if n > 1 => {
                pool.map(0..n, move |p| me.poll_partition(p))
            }
            _ => (0..n).map(|p| self.poll_partition(p)).collect(),
        };
        let mut stats = StreamStats::default();
        let mut wm: Option<Timestamp> = None;
        let mut wm_max: Option<Timestamp> = None;
        for round in rounds {
            let r = round?;
            stats.consumed += r.consumed;
            stats.records_emitted += r.records;
            stats.pipeline.add(r.stats);
            wm = fold_min_wm(wm, r.watermark);
            if r.watermark != Timestamp::MIN {
                wm_max = Some(wm_max.map_or(r.watermark, |cur| cur.max(r.watermark)));
            }
        }
        stats.watermark = wm;
        // Per-partition watermark skew: a stuck partition shows up here
        // (max races ahead of the min) before the table watermark — and
        // therefore freshness — visibly stalls.
        if let (Some(lo), Some(hi)) = (wm, wm_max) {
            stats.watermark_skew_secs = (hi - lo).max(0);
            self.deps.metrics.set_gauge(
                MetricKind::System,
                names::STREAM_WATERMARK_SKEW_SECS,
                stats.watermark_skew_secs as f64,
            );
        }
        // Log retention: reclaim the prefix every consumer group has
        // durably committed, clamped to the repair-retention floor.
        if let Some(ck) = self.deps.checkpoints.clone() {
            stats.truncated = self.truncate_log(&ck);
        }
        if stats.consumed > 0 {
            // Backlog shrank: unblock ingest_blocking waiters.
            self.drained.ping();
        }

        let now = self.deps.clock.now();
        // Online write stage: inline flush when pull-based, or when the
        // queue outruns the driver (backpressure).
        if self._writer_driver.is_none() || self.writer.pending() >= self.cfg.max_pending_online {
            self.writer.drain(&self.deps.online, now, wall_us());
        }
        stats.pending_online = self.writer.pending() as u64;

        // Watermark lag is the freshness signal.
        if let Some(wm) = wm {
            self.deps.freshness.advance(&self.table, wm);
            self.deps.metrics.set_gauge(
                MetricKind::System,
                names::STREAM_WATERMARK_LAG_SECS,
                (now - wm).max(0) as f64,
            );
        }
        self.deps.metrics.inc(MetricKind::System, names::STREAM_EVENTS_CONSUMED, stats.consumed);
        self.deps.metrics.inc(
            MetricKind::System,
            names::STREAM_RECORDS_EMITTED,
            stats.records_emitted,
        );
        Ok(stats)
    }

    /// Poll until the log is exhausted, then drain the online write
    /// stage — after this, every ingested event's effect is visible in
    /// both sinks (and queued for replicas).
    pub fn drain(&self) -> Result<StreamStats> {
        let mut agg = StreamStats::default();
        loop {
            let s = self.poll()?;
            agg.consumed += s.consumed;
            agg.records_emitted += s.records_emitted;
            agg.pipeline = s.pipeline; // cumulative since engine start
            agg.watermark = s.watermark;
            agg.watermark_skew_secs = s.watermark_skew_secs;
            agg.truncated += s.truncated;
            if s.consumed == 0 {
                break;
            }
        }
        self.writer.drain(&self.deps.online, self.deps.clock.now(), wall_us());
        agg.pending_online = 0;
        Ok(agg)
    }

    /// Reclaim source-log entries no consumer will ever need again:
    /// below the **minimum committed offset across all consumer groups**
    /// for the partition, and older than the partition's bin-aligned
    /// repair retention floor (minus the lookback halo). The second
    /// clamp matters because crash/resume rebuilds the partition buffer
    /// by replaying the log below the committed offset — events the
    /// rebuild still wants must survive even though every group has
    /// committed past them. Entries are scanned in arrival order and
    /// truncation stops at the first entry that is either uncommitted or
    /// still repair-relevant (prefix truncation only). Returns entries
    /// reclaimed. Wired into [`StreamIngestor::poll`] when
    /// `StreamDeps::checkpoints` is set; callers managing their own
    /// checkpoint store can invoke it directly.
    pub fn truncate_log(&self, store: &CheckpointStore) -> u64 {
        // Self-register: this engine's own uncommitted group must veto
        // truncation even when the caller's store is not the one in
        // `deps.checkpoints` (which registered at construction).
        store.register_consumer(&self.cfg.group, &self.table);
        let mut reclaimed = 0;
        for p in 0..self.parts.len() {
            // Cheapest guard first: with unbounded retention (the
            // default) there is never anything to reclaim, and the
            // checkpoint-map scan is skipped entirely.
            let evict_ts = {
                let st = self.parts[p].lock().unwrap();
                st.pipeline.evictable_below()
            };
            let Some(evict_ts) = evict_ts else { continue };
            let Some(committed) = store.min_committed_offset(&self.table, p) else { continue };
            let mut cut = self.log.base_offset(p);
            'scan: while cut < committed {
                let batch = self.log.read_from(p, cut, 256);
                if batch.is_empty() {
                    break;
                }
                for (off, ev) in &batch {
                    if *off >= committed || ev.ts >= evict_ts {
                        break 'scan;
                    }
                    cut = off + 1;
                }
            }
            reclaimed += self.log.truncate_below(p, cut);
        }
        reclaimed
    }

    /// Commit consumer progress behind a flush barrier: drain the online
    /// write stage, then record each partition's offset + finalization
    /// boundary. Everything below the committed offsets is durable in
    /// both **home** sinks; replica delivery is the fabric's job — the
    /// replication log is store-wide and outlives this engine, so
    /// batches emitted before a crash stay replayable to replicas
    /// regardless of checkpoint state.
    pub fn checkpoint_to(&self, store: &CheckpointStore) {
        // Phase 1: snapshot progress under each partition's lock. A
        // poll enqueues its online records *before* releasing the lock,
        // so every offset in the snapshot has its records either merged
        // (offline) or queued (online) by now.
        let snaps: Vec<PartitionCheckpoint> = self
            .parts
            .iter()
            .map(|part| {
                let st = part.lock().unwrap();
                let fin = st.pipeline.finalized_until();
                PartitionCheckpoint {
                    offset: st.next_offset,
                    finalized_until: (fin != Timestamp::MIN).then_some(fin),
                    last_creation: (st.last_creation != Timestamp::MIN)
                        .then_some(st.last_creation),
                }
            })
            .collect();
        // Phase 2: the flush barrier — everything queued up to the
        // snapshot becomes durable online. (Draining *after* the
        // snapshot is what makes a concurrent poll safe: its offsets are
        // past the snapshot and simply wait for the next checkpoint.)
        self.writer.drain(&self.deps.online, self.deps.clock.now(), wall_us());
        // Phase 3: commit — never ahead of the flush.
        for (p, ck) in snaps.into_iter().enumerate() {
            store.commit(&self.cfg.group, &self.table, p, ck);
        }
    }

    /// Crash/resume: restore consumer progress from `store` and rebuild
    /// each partition's working set by replaying the log below the
    /// committed offset. Must be called on a fresh engine (before any
    /// poll); events at/after the committed offsets re-process normally
    /// and re-deliveries are absorbed idempotently by the dual-write.
    pub fn restore_from(&self, store: &CheckpointStore) -> Result<()> {
        for (p, part) in self.parts.iter().enumerate() {
            let Some(ck) = store.get(&self.cfg.group, &self.table, p) else { continue };
            let mut st = part.lock().unwrap();
            if st.next_offset != 0 || st.pipeline.buffered_events() != 0 {
                return Err(FsError::Other(
                    "restore_from requires a fresh engine (partition already polled)".into(),
                ));
            }
            if let Some(fin) = ck.finalized_until {
                st.pipeline.restore_finalized(fin);
            }
            // Monotone creation stamps survive the restart: a repair of
            // a committed bin must out-version the pre-crash emission
            // even on a clock that has not advanced.
            if let Some(lc) = ck.last_creation {
                st.last_creation = st.last_creation.max(lc);
            }
            // Replay [base, committed): retention may have truncated a
            // prefix — those events are below the repair floor, so the
            // rebuild would have dropped them anyway.
            let base = self.log.base_offset(p);
            let replay = ck.offset.saturating_sub(base) as usize;
            for (_, ev) in self.log.read_from(p, base, replay) {
                st.pipeline.rebuild(&ev);
            }
            st.next_offset = ck.offset.min(self.log.high_water(p));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metadata::assets::SourceSpec;
    use crate::types::time::{Granularity, HOUR};
    use crate::types::{EntityInterner, FeatureWindow};

    fn spec(window_bins: usize) -> FeatureSetSpec {
        FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity(HOUR),
            window_bins,
        )
    }

    fn deps(clock: Clock) -> StreamDeps {
        StreamDeps {
            materializer: Arc::new(Materializer::new(None, Arc::new(EntityInterner::new()))),
            offline: Arc::new(OfflineStore::new()),
            online: Arc::new(OnlineStore::new(4)),
            freshness: Arc::new(FreshnessTracker::new()),
            metrics: Arc::new(MetricsRegistry::new()),
            clock,
            pool: None,
            fabric: None,
            checkpoints: None,
            tracer: None,
        }
    }

    fn ev(seq: u64, key: &str, ts: Timestamp, value: f32) -> StreamEvent {
        StreamEvent::new(seq, key, ts, value)
    }

    #[test]
    fn events_become_visible_in_both_sinks_after_watermark() {
        let clock = Clock::fixed(10 * HOUR);
        let ing = StreamIngestor::new(
            spec(2),
            StreamConfig { partitions: 2, ..Default::default() },
            deps(clock),
        )
        .unwrap();
        ing.ingest(&[ev(0, "a", 30 * 60, 5.0), ev(1, "a", HOUR + 10, 7.0)]).unwrap();
        let s = ing.poll().unwrap();
        assert_eq!(s.consumed, 2);
        // Watermark (lateness 0) = 1h10s → bin [0,1h) final; record at
        // event_ts 1h with sum 5 visible online + offline.
        let table = ing.table().to_string();
        assert_eq!(s.watermark, Some(HOUR + 10));
        assert!(s.records_emitted >= 1);
        let online = &ing.deps.online;
        let entity = ing.deps.materializer.interner().lookup("a").unwrap();
        let got = online.get(&table, entity, 10 * HOUR).unwrap();
        assert_eq!(got.event_ts, HOUR);
        assert_eq!(got.values[0], 5.0);
        assert_eq!(got.creation_ts, 10 * HOUR);
        let off = ing.deps.offline.scan(&table, FeatureWindow::new(0, 100 * HOUR));
        assert_eq!(off.len(), 1);
        assert_eq!(off[0].event_ts, HOUR);
        assert_eq!(off[0].creation_ts, 10 * HOUR);
        // Identical timestamps online/offline — the dual-write contract.
        assert_eq!(off[0].unique_key(), got.unique_key());
        // Freshness advanced to the watermark.
        let f = ing.deps.freshness.clone();
        f.configure(&table, 0, HOUR); // (engine only advances; SLA params are registration's job)
        ing.poll().unwrap();
        assert!(ing.deps.metrics.gauge("stream_watermark_lag_secs").is_some());
    }

    #[test]
    fn try_ingest_sheds_past_backlog_bound_and_recovers() {
        let clock = Clock::fixed(10 * HOUR);
        let ing = StreamIngestor::new(
            spec(2),
            StreamConfig { partitions: 2, max_backlog_events: 4, ..Default::default() },
            deps(clock),
        )
        .unwrap();
        ing.try_ingest(&[ev(0, "a", 10, 1.0), ev(1, "b", 20, 1.0), ev(2, "c", 30, 1.0)])
            .unwrap();
        assert_eq!(ing.backlog(), 3);
        // 3 queued + 2 incoming > 4 → typed shed, log untouched.
        match ing.try_ingest(&[ev(3, "d", 40, 1.0), ev(4, "e", 50, 1.0)]) {
            Err(FsError::Overloaded { .. }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(ing.backlog(), 3);
        assert_eq!(ing.deps.metrics.counter("stream_shed_events"), 2);
        // A batch that fits the remaining headroom is admitted.
        ing.try_ingest(&[ev(3, "d", 40, 1.0)]).unwrap();
        // Consuming the backlog re-opens admission.
        ing.poll().unwrap();
        assert_eq!(ing.backlog(), 0);
        ing.try_ingest(&[ev(4, "e", 50, 1.0), ev(5, "f", 60, 1.0)]).unwrap();
    }

    #[test]
    fn ingest_blocking_waits_for_drain_and_deadline_caps() {
        use std::time::Duration;
        let clock = Clock::fixed(10 * HOUR);
        let ing = StreamIngestor::new(
            spec(1),
            StreamConfig { partitions: 1, max_backlog_events: 2, ..Default::default() },
            deps(clock),
        )
        .unwrap();
        ing.ingest(&[ev(0, "a", 10, 1.0), ev(1, "a", 20, 1.0)]).unwrap();
        // Backlog full and nobody consuming: the deadline caps the wait.
        match ing.ingest_blocking(&[ev(2, "a", 30, 1.0)], Duration::from_millis(10)) {
            Err(FsError::Overloaded { .. }) => {}
            other => panic!("expected deadline-capped Overloaded, got {other:?}"),
        }
        assert_eq!(ing.deps.metrics.counter("stream_shed_events"), 1);
        // A concurrent poll drains the backlog and unblocks the producer
        // well before the generous deadline.
        let consumer = ing.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            consumer.poll().unwrap();
        });
        let n = ing.ingest_blocking(&[ev(2, "a", 30, 1.0)], Duration::from_secs(30)).unwrap();
        assert_eq!(n, 1);
        h.join().unwrap();
        assert_eq!(ing.deps.metrics.counter("stream_shed_events"), 1, "no shed on success");
    }

    #[test]
    fn duplicate_and_out_of_order_delivery_converges() {
        let clock = Clock::fixed(100 * HOUR);
        let ing = StreamIngestor::new(
            spec(1),
            StreamConfig { partitions: 3, ..Default::default() },
            deps(clock),
        )
        .unwrap();
        // Out of order within the same poll + duplicated seqs; the two
        // punctuation events at 10h push every touched partition's
        // watermark past the data bins.
        let events = vec![
            ev(2, "a", 2 * HOUR + 5, 3.0),
            ev(0, "a", 10, 1.0),
            ev(1, "a", HOUR + 10, 2.0),
            ev(0, "a", 10, 1.0), // dup
            ev(5, "b", 3 * HOUR + 1, 9.0),
            ev(2, "a", 2 * HOUR + 5, 3.0), // dup
            ev(7, "a", 10 * HOUR, 0.0),
            ev(8, "b", 10 * HOUR, 0.0),
        ];
        ing.ingest(&events).unwrap();
        let s = ing.drain().unwrap();
        assert_eq!(s.pipeline.duplicates, 2);
        let table = ing.table().to_string();
        // Offline holds one version per (entity, bin): a → bins 1h,2h,3h.
        let rows = ing.deps.offline.scan(&table, FeatureWindow::new(0, 100 * HOUR));
        let a = ing.deps.materializer.interner().lookup("a").unwrap();
        let mut a_bins: Vec<_> = rows.iter().filter(|r| r.entity == a).map(|r| r.event_ts).collect();
        a_bins.sort_unstable();
        assert_eq!(a_bins, vec![HOUR, 2 * HOUR, 3 * HOUR]);
        // Online holds the max-version record (Eq. 2).
        let got = ing.deps.online.get(&table, a, 100 * HOUR).unwrap();
        assert_eq!(got.event_ts, 3 * HOUR);
        assert_eq!(got.values[0], 3.0); // sum of bin [2h,3h)
    }

    #[test]
    fn late_event_repairs_both_sinks() {
        let clock = Clock::fixed(50 * HOUR);
        let ing = StreamIngestor::new(
            spec(2),
            StreamConfig { partitions: 1, ..Default::default() },
            deps(clock.clone()),
        )
        .unwrap();
        ing.ingest(&[ev(0, "a", 30, 1.0), ev(1, "a", 5 * HOUR, 0.5)]).unwrap();
        ing.drain().unwrap();
        let table = ing.table().to_string();
        let a = ing.deps.materializer.interner().lookup("a").unwrap();
        // Finalized to 5h: bins 1h and 2h emitted (wb=2 halo), online max
        // is the event-2h record with the original sum.
        let before = ing.deps.online.get(&table, a, i64::MAX - 1).unwrap();
        assert_eq!((before.event_ts, before.values[0]), (2 * HOUR, 1.0));
        // Late event for the already-final first bin.
        clock.set(51 * HOUR);
        ing.ingest(&[ev(2, "a", 40, 10.0)]).unwrap();
        let s = ing.drain().unwrap();
        assert_eq!(s.pipeline.late, 1);
        // Online: the repair re-emits bins [0,2h); the event-2h version
        // with the newer creation_ts overrides (Eq. 2) and now includes
        // the late value (1 + 10).
        let after = ing.deps.online.get(&table, a, i64::MAX - 1).unwrap();
        assert_eq!((after.event_ts, after.creation_ts), (2 * HOUR, 51 * HOUR));
        assert_eq!(after.values[0], 11.0);
        // Offline: the repaired bin keeps both creation versions (Eq. 1),
        // old value next to the late-inclusive recompute.
        let rows = ing.deps.offline.scan(&table, FeatureWindow::new(0, HOUR + 1));
        let mut versions: Vec<_> = rows.iter().map(|r| (r.creation_ts, r.values[0])).collect();
        versions.sort_by_key(|&(c, _)| c);
        assert_eq!(versions, vec![(50 * HOUR, 1.0), (51 * HOUR, 11.0)]);
    }

    #[test]
    fn pool_fanout_matches_sequential() {
        let mk = |pool: Option<Arc<ThreadPool>>| {
            let clock = Clock::fixed(99 * HOUR);
            let mut d = deps(clock);
            d.pool = pool;
            StreamIngestor::new(
                spec(3),
                StreamConfig { partitions: 4, ..Default::default() },
                d,
            )
            .unwrap()
        };
        let seq = mk(None);
        let par = mk(Some(Arc::new(ThreadPool::new(4))));
        let mut rng = crate::util::rng::Rng::new(7);
        let events: Vec<StreamEvent> = (0..400)
            .map(|i| {
                ev(
                    i,
                    &format!("cust_{}", rng.below(12)),
                    rng.range(0, 24 * HOUR),
                    rng.f32(),
                )
            })
            .collect();
        seq.ingest(&events).unwrap();
        par.ingest(&events).unwrap();
        seq.drain().unwrap();
        par.drain().unwrap();
        let table = seq.table().to_string();
        let a = seq.deps.offline.scan(&table, FeatureWindow::new(0, 100 * HOUR));
        let b = par.deps.offline.scan(&table, FeatureWindow::new(0, 100 * HOUR));
        // Entity ids are interner-local but keys intern in different
        // orders; compare via resolved keys.
        let key_of = |ing: &StreamIngestor, e| ing.deps.materializer.interner().resolve(e).unwrap();
        let norm = |ing: &StreamIngestor, rows: &[crate::types::FeatureRecord]| {
            let mut v: Vec<(String, Timestamp, Vec<f32>)> = rows
                .iter()
                .map(|r| (key_of(ing, r.entity), r.event_ts, r.values.to_vec()))
                .collect();
            v.sort_by(|x, y| (&x.0, x.1).cmp(&(&y.0, y.1)));
            v
        };
        assert_eq!(norm(&seq, &a), norm(&par, &b));
        assert!(!a.is_empty());
    }

    #[test]
    fn emitted_batches_reach_replicas_through_the_fabric() {
        let clock = Clock::fixed(10 * HOUR);
        let eu = Arc::new(OnlineStore::new(2));
        let fabric =
            ReplicationFabric::new(2, vec![("westeurope".into(), eu.clone(), 60)], None);
        let mut d = deps(clock.clone());
        d.fabric = Some(fabric.clone());
        let ing = StreamIngestor::new(spec(1), StreamConfig::default(), d).unwrap();
        ing.ingest(&[ev(0, "a", 10, 4.0), ev(1, "a", HOUR + 5, 1.0)]).unwrap();
        ing.drain().unwrap();
        let table = ing.table().to_string();
        let a = ing.deps.materializer.interner().lookup("a").unwrap();
        // Home is visible immediately; the replica only after its lag.
        assert!(ing.deps.online.get(&table, a, 10 * HOUR).is_some());
        fabric.pump(10 * HOUR);
        assert!(eu.get(&table, a, 10 * HOUR).is_none());
        let applied = fabric.pump(10 * HOUR + 60);
        assert!(applied["westeurope"] > 0);
        assert_eq!(eu.get(&table, a, 10 * HOUR + 60).unwrap().values[0], 4.0);
        // The fabric log — not the engine — retains the batches, so the
        // replication history survives the engine: dropping the engine
        // leaves the applied prefix reclaimable.
        drop(ing);
        assert!(fabric.truncate_applied() > 0);
    }

    #[test]
    fn watermark_skew_gauge_exposes_stuck_partition() {
        let clock = Clock::fixed(50 * HOUR);
        let ing = StreamIngestor::new(
            spec(1),
            StreamConfig { partitions: 2, ..Default::default() },
            deps(clock),
        )
        .unwrap();
        // Find keys landing in different partitions.
        let (mut key_a, mut key_b) = (None, None);
        for i in 0..64 {
            let k = format!("cust_{i}");
            match ing.log().partition_of(&k) {
                0 if key_a.is_none() => key_a = Some(k),
                1 if key_b.is_none() => key_b = Some(k),
                _ => {}
            }
            if key_a.is_some() && key_b.is_some() {
                break;
            }
        }
        let (a, b) = (key_a.unwrap(), key_b.unwrap());
        // Partition of `a` runs 9 hours ahead of `b`'s: the table
        // watermark (min) sits at 1h while the skew gauge exposes the
        // laggard long before freshness notices.
        ing.ingest(&[ev(0, &a, 10 * HOUR, 1.0), ev(1, &b, HOUR, 1.0)]).unwrap();
        let s = ing.poll().unwrap();
        assert_eq!(s.watermark, Some(HOUR));
        assert_eq!(s.watermark_skew_secs, 9 * HOUR);
        assert_eq!(
            ing.deps.metrics.gauge("stream_watermark_skew_secs"),
            Some((9 * HOUR) as f64)
        );
        // The stuck partition catches up → skew collapses.
        ing.ingest(&[ev(2, &b, 10 * HOUR, 1.0)]).unwrap();
        let s = ing.poll().unwrap();
        assert_eq!(s.watermark_skew_secs, 0);
        assert_eq!(ing.deps.metrics.gauge("stream_watermark_skew_secs"), Some(0.0));
    }

    #[test]
    fn log_retention_truncates_committed_prefix_and_survives_resume() {
        let clock = Clock::fixed(100 * HOUR);
        let store = Arc::new(CheckpointStore::new());
        let mut d = deps(clock.clone());
        d.checkpoints = Some(store.clone());
        let cfg = StreamConfig {
            partitions: 1,
            retention_secs: 2 * HOUR,
            ..Default::default()
        };
        let ing = StreamIngestor::new(spec(1), cfg.clone(), d).unwrap();
        let events: Vec<StreamEvent> =
            (0..20).map(|i| ev(i, "a", i as i64 * HOUR + 30 * 60, 1.0)).collect();
        ing.ingest(&events).unwrap();
        ing.drain().unwrap();
        // No checkpoint committed yet → nothing truncated.
        assert_eq!(ing.log().base_offset(0), 0);

        ing.checkpoint_to(&store);
        let s = ing.poll().unwrap();
        // Finalized to 19h, retention floor 17h (lookback 0): committed
        // events with ts < 17h are reclaimed, the repair halo survives.
        assert_eq!(s.truncated, 17);
        assert_eq!(ing.log().base_offset(0), 17);
        assert_eq!(ing.log().len(), 3);
        assert_eq!(ing.log().high_water(0), 20);

        // Crash/resume over the truncated log: a fresh engine restores
        // from the checkpoint, replays only the retained suffix, and
        // keeps processing.
        let d2 = {
            let mut d2 = deps(clock.clone());
            d2.checkpoints = Some(store.clone());
            d2
        };
        let ing2 = StreamIngestor::with_log(spec(1), cfg, d2, ing.log().clone()).unwrap();
        ing2.restore_from(&store).unwrap();
        ing2.ingest(&[ev(50, "a", 20 * HOUR + 10, 2.0)]).unwrap();
        let s2 = ing2.drain().unwrap();
        assert!(s2.records_emitted > 0, "resumed engine must emit the newly-final bin");
        let table = ing2.table().to_string();
        let a = ing2.deps.materializer.interner().lookup("a").unwrap();
        // The emitted bin is 19h→20h with the retained 19h30 event.
        let got = ing2.deps.online.get(&table, a, i64::MAX - 1).unwrap();
        assert_eq!(got.event_ts, 20 * HOUR);
        assert_eq!(got.values[0], 1.0);
    }

    #[test]
    fn rejects_bad_configs() {
        let clock = Clock::fixed(0);
        assert!(StreamIngestor::new(
            spec(1),
            StreamConfig { partitions: 0, ..Default::default() },
            deps(clock.clone())
        )
        .is_err());
        assert!(StreamIngestor::new(
            spec(1),
            StreamConfig { max_bins_per_emit: 0, ..Default::default() },
            deps(clock.clone())
        )
        .is_err());
        let mut bad = spec(1);
        bad.window_bins = 0;
        assert!(StreamIngestor::new(bad, StreamConfig::default(), deps(clock)).is_err());
    }
}
