//! The per-partition incremental materialization pipeline.
//!
//! Each log partition owns one [`PartitionPipeline`]: a replayable
//! event buffer, a seq-dedupe set, a [`WatermarkTracker`], and the
//! bin-finalization boundary. The pipeline itself performs **no
//! compute and no I/O** — it absorbs events and produces [`EmitPlan`]s
//! (aligned feature windows, optionally restricted to the entities a
//! late event touched). The engine executes each plan through the same
//! `materialize::calc` Algorithm-1 path the batch scheduler uses, so a
//! streamed record is *by construction* the record a batch job over the
//! same events would produce — the whole online≡offline differential
//! guarantee reduces to "same calc, same inputs, watermark-gated
//! creation time".
//!
//! # Emission
//!
//! When the watermark passes a bin end, that bin is *final*: the plan
//! covers all newly-final bins as one window (the engine splits it by
//! `max_bins_per_job`-style chunks). Rolling windows reach back into
//! the retained buffer for their lookback halo, exactly like Algorithm
//! 1's `source_window`.
//!
//! # Late events (bounded out-of-orderness violated)
//!
//! An event whose bin is already final is routed to the repair path:
//! the bins its rolling window touches — `[bin, bin + window_bins)`
//! clipped to the already-final region — are recomputed **for that
//! entity only**, producing new record versions with a fresh
//! `creation_ts`. Online, Eq. 2 overrides (same `event_ts`, newer
//! `creation_ts`); offline, the new version is appended next to the old
//! one — the same late-data shape the paper's Fig 5 R3 describes for
//! the batch path, so PIT queries keep working unchanged.
//!
//! # Memory
//!
//! The buffer retains events down to
//! `finalized_until − retention − lookback` (retention `i64::MAX` =
//! keep everything). A late event older than the retention floor cannot
//! be repaired correctly (its window's inputs are gone) and is counted
//! in `dropped_late` instead of producing a wrong record.

use std::collections::{HashMap, HashSet};

use super::log::StreamEvent;
use super::watermark::WatermarkTracker;
use crate::source::{Event, SourceConnector};
use crate::types::{FeatureWindow, Granularity, Result, Timestamp};

/// Static shape of one partition pipeline.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    pub granularity: Granularity,
    /// Rolling window length in bins (drives the lookback halo).
    pub window_bins: usize,
    /// Bounded out-of-orderness: the watermark trails max event time by
    /// this many seconds.
    pub allowed_lateness_secs: i64,
    /// How far below the finalization boundary late events are still
    /// repairable; `i64::MAX` retains everything.
    pub retention_secs: i64,
}

impl PipelineConfig {
    fn lookback_secs(&self) -> i64 {
        (self.window_bins.max(1) as i64 - 1) * self.granularity.secs()
    }
}

/// One unit of materialization work the engine must run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EmitPlan {
    /// Granularity-aligned feature window to materialize.
    pub window: FeatureWindow,
    /// Restrict the compute to these entity keys (`None` = every entity
    /// with buffered events — the normal emission path).
    pub keys: Option<Vec<String>>,
    /// True when this plan re-materializes already-final bins for late
    /// events.
    pub repair: bool,
}

/// Per-partition counters (fed into `StreamStats` / metrics).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PartitionStats {
    /// Events absorbed (including duplicates and drops).
    pub received: u64,
    /// Producer redeliveries suppressed by seq dedupe.
    pub duplicates: u64,
    /// Events that arrived out of order but within the lateness bound.
    pub out_of_order: u64,
    /// Events below the finalization boundary (repair path).
    pub late: u64,
    /// Late events older than the retention floor — not repairable.
    pub dropped_late: u64,
    /// Normal emission plans produced.
    pub emitted_windows: u64,
    /// Repair plans produced.
    pub repaired_windows: u64,
}

impl PartitionStats {
    pub fn add(&mut self, o: PartitionStats) {
        self.received += o.received;
        self.duplicates += o.duplicates;
        self.out_of_order += o.out_of_order;
        self.late += o.late;
        self.dropped_late += o.dropped_late;
        self.emitted_windows += o.emitted_windows;
        self.repaired_windows += o.repaired_windows;
    }
}

/// The per-partition state machine.
#[derive(Debug)]
pub struct PartitionPipeline {
    cfg: PipelineConfig,
    tracker: WatermarkTracker,
    /// Retained events (replayable working set; arbitrary order).
    buffer: Vec<StreamEvent>,
    /// Producer-seq dedupe set.
    seen: HashSet<u64>,
    /// Bins with end ≤ this boundary are final. `i64::MIN` = none yet.
    finalized_until: Timestamp,
    /// key → late-event bin starts awaiting repair.
    pending_repairs: HashMap<String, Vec<Timestamp>>,
    pub stats: PartitionStats,
}

impl PartitionPipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        assert!(cfg.window_bins >= 1);
        assert!(cfg.retention_secs >= 0);
        PartitionPipeline {
            tracker: WatermarkTracker::new(cfg.allowed_lateness_secs),
            cfg,
            buffer: Vec::new(),
            seen: HashSet::new(),
            finalized_until: Timestamp::MIN,
            pending_repairs: HashMap::new(),
            stats: PartitionStats::default(),
        }
    }

    pub fn watermark(&self) -> Timestamp {
        self.tracker.watermark()
    }

    pub fn finalized_until(&self) -> Timestamp {
        self.finalized_until
    }

    pub fn buffer(&self) -> &[StreamEvent] {
        &self.buffer
    }

    pub fn buffered_events(&self) -> usize {
        self.buffer.len()
    }

    /// Oldest *bin start* still repairable, aligned down to a bin
    /// boundary: a bin is only repairable if **all** of its events are
    /// still buffered, so the floor must never cut a bin in half —
    /// otherwise a late event could pass the repairability check while
    /// part of its bin's inputs were already evicted, and the repair
    /// would silently produce a wrong value. Late events whose bin
    /// starts below this are dropped (counted) rather than mis-repaired.
    fn retention_floor(&self) -> Option<Timestamp> {
        if self.cfg.retention_secs == i64::MAX || self.finalized_until == Timestamp::MIN {
            return None;
        }
        self.finalized_until
            .checked_sub(self.cfg.retention_secs)
            .map(|t| self.cfg.granularity.floor(t))
    }

    /// Events with `ts` below this bound can no longer contribute to any
    /// repair: the bin-aligned retention floor minus the rolling-window
    /// lookback halo. This is both the buffer-eviction bound and the
    /// **safe log-truncation bound** for this partition — a replayed
    /// event below it would be dropped by `rebuild` anyway, so the log
    /// may reclaim it once every consumer group's checkpoint has passed
    /// it (`StreamIngestor::truncate_log`). `None` while retention is
    /// unbounded or nothing has finalized.
    pub fn evictable_below(&self) -> Option<Timestamp> {
        self.retention_floor().and_then(|f| f.checked_sub(self.cfg.lookback_secs()))
    }

    /// Absorb one event: dedupe, classify, buffer, queue repairs.
    pub fn absorb(&mut self, ev: &StreamEvent) {
        self.stats.received += 1;
        if !self.seen.insert(ev.seq) {
            self.stats.duplicates += 1;
            return;
        }
        let g = self.cfg.granularity;
        let bin_start = g.floor(ev.ts);
        let bin_end = bin_start + g.secs();
        let late = self.finalized_until != Timestamp::MIN && bin_end <= self.finalized_until;
        let obs = self.tracker.observe(&ev.key, ev.ts);
        if obs.out_of_order && !late {
            self.stats.out_of_order += 1;
        }
        if late {
            if self.retention_floor().is_some_and(|floor| bin_start < floor) {
                self.stats.dropped_late += 1;
                return;
            }
            self.stats.late += 1;
            self.pending_repairs.entry(ev.key.clone()).or_default().push(bin_start);
        }
        self.buffer.push(ev.clone());
    }

    /// Advance finalization to the watermark and produce the round's
    /// plans: at most one normal emission window plus the repair windows
    /// for late events absorbed since the last round. Also evicts the
    /// buffer below the retention floor.
    pub fn plans(&mut self) -> Vec<EmitPlan> {
        let g = self.cfg.granularity;
        let mut out = Vec::new();

        // Repairs first: their windows are clipped to the boundary as it
        // stood when the late events arrived — bins finalized *this*
        // round are emitted below with the late events already in the
        // buffer, so repairing them too would do the work twice.
        let repair_cap = self.finalized_until;
        if !self.pending_repairs.is_empty() && repair_cap != Timestamp::MIN {
            // Merge each key's touched bins into intervals, then group
            // keys sharing an identical interval into one plan.
            let wb_span = self.cfg.window_bins as i64 * g.secs();
            let mut by_interval: HashMap<(Timestamp, Timestamp), Vec<String>> = HashMap::new();
            for (key, mut bins) in std::mem::take(&mut self.pending_repairs) {
                bins.sort_unstable();
                bins.dedup();
                let mut cur: Option<(Timestamp, Timestamp)> = None;
                for b in bins {
                    let end = b.saturating_add(wb_span).min(repair_cap);
                    debug_assert!(b < end, "late bin must precede the finalization boundary");
                    match cur {
                        Some((s, e)) if b <= e => cur = Some((s, e.max(end))),
                        Some(done) => {
                            by_interval.entry(done).or_default().push(key.clone());
                            cur = Some((b, end));
                        }
                        None => cur = Some((b, end)),
                    }
                }
                if let Some(done) = cur {
                    by_interval.entry(done).or_default().push(key.clone());
                }
            }
            let mut intervals: Vec<((Timestamp, Timestamp), Vec<String>)> =
                by_interval.into_iter().collect();
            intervals.sort(); // deterministic plan order
            for ((s, e), mut keys) in intervals {
                keys.sort();
                self.stats.repaired_windows += 1;
                out.push(EmitPlan { window: FeatureWindow::new(s, e), keys: Some(keys), repair: true });
            }
        }

        // Normal emission: all bins newly covered by the watermark.
        let wm = self.watermark();
        if wm != Timestamp::MIN {
            let new_final = g.floor(wm);
            if new_final > self.finalized_until {
                let start = if self.finalized_until == Timestamp::MIN {
                    self.buffer.iter().map(|e| g.floor(e.ts)).min()
                } else {
                    Some(self.finalized_until)
                };
                if let Some(s) = start {
                    if s < new_final && self.buffer.iter().any(|e| e.ts < new_final) {
                        self.stats.emitted_windows += 1;
                        out.push(EmitPlan {
                            window: FeatureWindow::new(s.min(new_final), new_final),
                            keys: None,
                            repair: false,
                        });
                    }
                }
                self.finalized_until = new_final;
            }
        }

        // Evict below the retention floor (keep the repair lookback halo).
        if let Some(evict_below) = self.evictable_below() {
            let seen = &mut self.seen;
            self.buffer.retain(|e| {
                let keep = e.ts >= evict_below;
                if !keep {
                    seen.remove(&e.seq);
                }
                keep
            });
        }
        out
    }

    /// Crash/resume: re-absorb one already-committed event to rebuild
    /// the working set — buffer + dedupe + watermark only, **no** plan
    /// side effects (its emissions and repairs were durable before the
    /// checkpoint committed).
    pub fn rebuild(&mut self, ev: &StreamEvent) {
        if !self.seen.insert(ev.seq) {
            return;
        }
        self.tracker.observe(&ev.key, ev.ts);
        let bin_start = self.cfg.granularity.floor(ev.ts);
        if self.retention_floor().is_some_and(|floor| bin_start < floor) {
            return;
        }
        self.buffer.push(ev.clone());
    }

    /// Crash/resume: restore the checkpointed finalization boundary
    /// (call before [`PartitionPipeline::rebuild`], so the retention
    /// floor applies during the replay).
    pub fn restore_finalized(&mut self, t: Timestamp) {
        self.finalized_until = self.finalized_until.max(t);
    }
}

/// A `SourceConnector` over the partition buffer — Algorithm 1's
/// `source.read` served straight from retained stream events, so the
/// engine can reuse `Materializer::calculate` verbatim. Optionally
/// restricted to the entity keys a repair plan names.
pub struct BufferSource<'a> {
    events: &'a [StreamEvent],
    keys: Option<HashSet<&'a str>>,
}

impl<'a> BufferSource<'a> {
    pub fn new(events: &'a [StreamEvent], keys: Option<&'a [String]>) -> Self {
        BufferSource { events, keys: keys.map(|ks| ks.iter().map(String::as_str).collect()) }
    }
}

impl SourceConnector for BufferSource<'_> {
    fn read(&self, window: FeatureWindow, as_of: Timestamp) -> Result<Vec<Event>> {
        Ok(self
            .events
            .iter()
            .filter(|e| window.contains(e.ts) && e.ts <= as_of)
            .filter(|e| self.keys.as_ref().is_none_or(|ks| ks.contains(e.key.as_str())))
            .map(|e| Event { key: e.key.clone(), ts: e.ts, value: e.value })
            .collect())
    }

    fn describe(&self) -> String {
        format!("stream-buffer({} events)", self.events.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::HOUR;

    fn cfg(wb: usize, lateness: i64, retention: i64) -> PipelineConfig {
        PipelineConfig {
            granularity: Granularity(HOUR),
            window_bins: wb,
            allowed_lateness_secs: lateness,
            retention_secs: retention,
        }
    }

    fn ev(seq: u64, key: &str, ts: Timestamp) -> StreamEvent {
        StreamEvent::new(seq, key, ts, 1.0)
    }

    #[test]
    fn emits_only_watermark_covered_bins() {
        let mut p = PartitionPipeline::new(cfg(2, 600, i64::MAX));
        p.absorb(&ev(0, "a", 100));
        assert!(p.plans().is_empty(), "watermark below first bin end");
        // max_seen = HOUR + 700 → wm = HOUR + 100 → bin [0, HOUR) final.
        p.absorb(&ev(1, "a", HOUR + 700));
        let plans = p.plans();
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].window, FeatureWindow::new(0, HOUR));
        assert!(!plans[0].repair && plans[0].keys.is_none());
        assert_eq!(p.finalized_until(), HOUR);
        // No progress → no new plans.
        assert!(p.plans().is_empty());
    }

    #[test]
    fn duplicates_suppressed() {
        let mut p = PartitionPipeline::new(cfg(1, 0, i64::MAX));
        p.absorb(&ev(0, "a", 100));
        p.absorb(&ev(0, "a", 100));
        p.absorb(&ev(0, "a", 100));
        assert_eq!(p.stats.duplicates, 2);
        assert_eq!(p.buffered_events(), 1);
    }

    #[test]
    fn late_event_routes_to_entity_scoped_repair() {
        let mut p = PartitionPipeline::new(cfg(2, 0, i64::MAX));
        p.absorb(&ev(0, "a", 100));
        p.absorb(&ev(1, "b", 3 * HOUR + 10));
        let plans = p.plans(); // finalizes [0, 3h)
        assert_eq!(plans.len(), 1);
        assert_eq!(p.finalized_until(), 3 * HOUR);
        // Event for the already-final bin [0, 1h): late.
        p.absorb(&ev(2, "a", 50));
        assert_eq!(p.stats.late, 1);
        let plans = p.plans();
        assert_eq!(plans.len(), 1);
        let r = &plans[0];
        assert!(r.repair);
        // Rolling window of 2 bins starting at the event's bin, clipped
        // to the finalized boundary.
        assert_eq!(r.window, FeatureWindow::new(0, 2 * HOUR));
        assert_eq!(r.keys.as_deref(), Some(&["a".to_string()][..]));
        // The late event stays buffered for future halos.
        assert_eq!(p.buffered_events(), 3);
    }

    #[test]
    fn repair_intervals_merge_and_group_by_key() {
        let mut p = PartitionPipeline::new(cfg(2, 0, i64::MAX));
        p.absorb(&ev(0, "z", 10 * HOUR + 5));
        p.plans(); // finalized to 10h
        // Two adjacent late bins for "a" merge into one interval; "b"
        // shares an identical interval with "a"'s first … construct:
        p.absorb(&ev(1, "a", 30)); // bin 0 → window [0, 2h)
        p.absorb(&ev(2, "a", HOUR + 30)); // bin 1 → [1h, 3h) — overlaps → [0, 3h)
        p.absorb(&ev(3, "b", 30)); // bin 0 → [0, 2h)
        let plans = p.plans();
        assert_eq!(plans.len(), 2);
        let a = plans.iter().find(|pl| pl.keys.as_deref() == Some(&["a".to_string()][..])).unwrap();
        assert_eq!(a.window, FeatureWindow::new(0, 3 * HOUR));
        let b = plans.iter().find(|pl| pl.keys.as_deref() == Some(&["b".to_string()][..])).unwrap();
        assert_eq!(b.window, FeatureWindow::new(0, 2 * HOUR));
        assert_eq!(p.stats.repaired_windows, 2);
    }

    #[test]
    fn repair_clips_to_finalized_boundary() {
        let mut p = PartitionPipeline::new(cfg(4, 0, i64::MAX));
        p.absorb(&ev(0, "z", 3 * HOUR + 5));
        p.plans(); // finalized to 3h
        p.absorb(&ev(1, "a", 2 * HOUR + 1)); // bin [2h,3h) final → late
        let plans = p.plans();
        let r = plans.iter().find(|pl| pl.repair).unwrap();
        // 4-bin span would reach 6h; clipped to the 3h boundary.
        assert_eq!(r.window, FeatureWindow::new(2 * HOUR, 3 * HOUR));
    }

    #[test]
    fn retention_floor_drops_unrepairable_events() {
        let mut p = PartitionPipeline::new(cfg(1, 0, 2 * HOUR));
        p.absorb(&ev(0, "z", 10 * HOUR + 5));
        p.plans(); // finalized 10h; floor = 8h
        p.absorb(&ev(1, "a", 7 * HOUR)); // below floor → dropped
        p.absorb(&ev(2, "a", 9 * HOUR)); // above floor → repairable
        assert_eq!(p.stats.dropped_late, 1);
        assert_eq!(p.stats.late, 1);
        let plans = p.plans();
        assert_eq!(plans.iter().filter(|pl| pl.repair).count(), 1);
    }

    #[test]
    fn unaligned_retention_floor_never_splits_a_bin() {
        // retention 90min (not a bin multiple): the floor aligns down to
        // 8h, so bin [8h,9h) is either fully repairable with all its
        // events retained, or fully dropped — never half-evicted.
        let mut p = PartitionPipeline::new(cfg(1, 0, 90 * 60));
        p.absorb(&ev(0, "a", 8 * HOUR + 60)); // early event of bin [8h,9h)
        p.absorb(&ev(1, "z", 10 * HOUR + 5));
        p.plans(); // finalized 10h; aligned floor = 8h
        // Early bin-8h event must survive eviction (bin above the floor).
        assert_eq!(p.buffered_events(), 2);
        // Late event in the same bin: repairable, and the recompute sees
        // the retained early event.
        p.absorb(&ev(2, "a", 8 * HOUR + 30 * 60));
        let plans = p.plans();
        let r = plans.iter().find(|pl| pl.repair).unwrap();
        assert_eq!(r.window, FeatureWindow::new(8 * HOUR, 9 * HOUR));
        let src = BufferSource::new(p.buffer(), r.keys.as_deref());
        let got = src.read(r.window, i64::MAX).unwrap();
        assert_eq!(got.len(), 2, "repair inputs must include the bin's early event");
        // A late event below the aligned floor is dropped outright.
        p.absorb(&ev(3, "a", 7 * HOUR + 59 * 60));
        assert_eq!(p.stats.dropped_late, 1);
    }

    #[test]
    fn buffer_evicts_below_retention_and_frees_dedupe() {
        let mut p = PartitionPipeline::new(cfg(1, 0, HOUR));
        for i in 0..10 {
            p.absorb(&ev(i, "a", i as i64 * HOUR + 5));
        }
        p.plans(); // finalized 9h, floor 8h, lookback 0 → evict < 8h
        assert!(p.buffered_events() <= 2, "old events evicted, got {}", p.buffered_events());
        // Evicted seqs are forgotten — a redelivery of seq 0 is treated
        // as (too-old) late, not a duplicate.
        p.absorb(&ev(0, "a", 5));
        assert_eq!(p.stats.duplicates, 0);
        assert_eq!(p.stats.dropped_late, 1);
    }

    #[test]
    fn rebuild_restores_working_set_without_side_effects() {
        let mut p = PartitionPipeline::new(cfg(2, 0, i64::MAX));
        p.restore_finalized(3 * HOUR);
        for i in 0..5 {
            p.rebuild(&ev(i, "a", i as i64 * HOUR + 30));
        }
        p.rebuild(&ev(2, "a", 2 * HOUR + 30)); // duplicate in replay
        assert_eq!(p.buffered_events(), 5);
        assert_eq!(p.finalized_until(), 3 * HOUR);
        assert_eq!(p.stats, PartitionStats::default(), "rebuild must not count stats");
        // Resuming: watermark restored from replayed events, so new
        // plans cover only [3h, …).
        p.absorb(&ev(10, "a", 6 * HOUR + 5));
        let plans = p.plans();
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].window, FeatureWindow::new(3 * HOUR, 6 * HOUR));
    }

    #[test]
    fn buffer_source_filters_window_and_keys() {
        let events =
            vec![ev(0, "a", 10), ev(1, "b", 20), ev(2, "a", 30), ev(3, "a", 99)];
        let all = BufferSource::new(&events, None);
        let got = all.read(FeatureWindow::new(0, 50), i64::MAX).unwrap();
        assert_eq!(got.len(), 3);
        let keys = vec!["a".to_string()];
        let only_a = BufferSource::new(&events, Some(&keys));
        let got = only_a.read(FeatureWindow::new(0, 100), i64::MAX).unwrap();
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(|e| e.key == "a"));
        assert!(only_a.describe().contains("4 events"));
    }
}
