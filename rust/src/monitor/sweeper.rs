//! Background TTL sweeper (ROADMAP follow-up: `evict_expired` used to
//! be caller-driven).
//!
//! Redis reclaims expired keys both lazily (on read — our stores
//! already filter expired entries at read time) and **actively** (a
//! background cycle). [`TtlSweeper`] is the active half: a thread that
//! periodically sweeps the online store and folds the results into the
//! monitoring plane — eviction counters plus the freshness-SLA
//! violation gauge, so one health cycle answers both "is expired data
//! still resident?" and "which tables are stale?".
//!
//! The sweep body is exposed as [`sweep_once`] so tests and the
//! coordinator can run a deterministic cycle on the simulated clock;
//! the thread just repeats it on a wall-clock period.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::monitor::freshness::FreshnessTracker;
use crate::monitor::metrics::{MetricKind, MetricsRegistry};
use crate::monitor::names;
use crate::online_store::OnlineStore;
use crate::types::Timestamp;
use crate::util::Clock;

/// Outcome of one sweep cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SweepReport {
    /// Expired entries physically reclaimed from the online store.
    pub evicted: u64,
    /// Tables currently violating their freshness SLA.
    pub sla_violations: usize,
}

/// One sweep cycle: reclaim expired online entries and refresh the
/// freshness gauges.
pub fn sweep_once(
    online: &OnlineStore,
    freshness: &FreshnessTracker,
    metrics: &MetricsRegistry,
    now: Timestamp,
) -> SweepReport {
    let evicted = online.evict_expired(now);
    if evicted > 0 {
        metrics.inc(MetricKind::System, names::TTL_EVICTED_TOTAL, evicted);
    }
    let violations = freshness.violations(now);
    metrics.set_gauge(MetricKind::System, names::FRESHNESS_SLA_VIOLATIONS, violations.len() as f64);
    metrics.set_gauge(MetricKind::System, names::TTL_LAST_SWEEP_AT, now as f64);
    SweepReport { evicted, sla_violations: violations.len() }
}

/// Background sweep thread; stops (promptly) on drop.
pub struct TtlSweeper {
    stop: Arc<(Mutex<bool>, Condvar)>,
    total_evicted: Arc<AtomicU64>,
    sweeps: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TtlSweeper {
    pub fn spawn(
        online: Arc<OnlineStore>,
        freshness: Arc<FreshnessTracker>,
        metrics: Arc<MetricsRegistry>,
        clock: Clock,
        period: Duration,
    ) -> TtlSweeper {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let total_evicted = Arc::new(AtomicU64::new(0));
        let sweeps = Arc::new(AtomicU64::new(0));
        let (stop2, evicted2, sweeps2) = (stop.clone(), total_evicted.clone(), sweeps.clone());
        let handle = std::thread::Builder::new()
            .name("geofs-ttl-sweeper".into())
            .spawn(move || loop {
                {
                    let (m, cv) = &*stop2;
                    let mut stopped = m.lock().unwrap();
                    while !*stopped {
                        let (g, timeout) = cv.wait_timeout(stopped, period).unwrap();
                        stopped = g;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    if *stopped {
                        return;
                    }
                }
                let report = sweep_once(&online, &freshness, &metrics, clock.now());
                evicted2.fetch_add(report.evicted, Ordering::Relaxed);
                sweeps2.fetch_add(1, Ordering::Relaxed);
            })
            .expect("spawn ttl sweeper");
        TtlSweeper { stop, total_evicted, sweeps, handle: Some(handle) }
    }

    /// Entries reclaimed by the background thread so far.
    pub fn total_evicted(&self) -> u64 {
        self.total_evicted.load(Ordering::Relaxed)
    }

    /// Completed background cycles.
    pub fn sweeps(&self) -> u64 {
        self.sweeps.load(Ordering::Relaxed)
    }
}

impl Drop for TtlSweeper {
    fn drop(&mut self) {
        {
            let (m, cv) = &*self.stop;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::FeatureRecord;

    fn setup() -> (Arc<OnlineStore>, Arc<FreshnessTracker>, Arc<MetricsRegistry>) {
        let online = Arc::new(OnlineStore::new(2));
        online.set_ttl("t", 100);
        let recs: Vec<FeatureRecord> =
            (0..8).map(|i| FeatureRecord::new(i, 10, 20, vec![i as f32])).collect();
        online.merge("t", &recs, 1_000);
        (online, Arc::new(FreshnessTracker::new()), Arc::new(MetricsRegistry::new()))
    }

    #[test]
    fn sweep_once_reclaims_and_gauges() {
        let (online, freshness, metrics) = setup();
        freshness.configure("t", 0, 50);
        freshness.advance("t", 900);
        // Nothing expired yet.
        let r = sweep_once(&online, &freshness, &metrics, 1_050);
        assert_eq!(r.evicted, 0);
        assert_eq!(online.len(), 8);
        // Past the TTL: all reclaimed; table is also past its SLA.
        let r = sweep_once(&online, &freshness, &metrics, 1_100);
        assert_eq!(r.evicted, 8);
        assert_eq!(r.sla_violations, 1);
        assert_eq!(online.len(), 0);
        assert_eq!(metrics.counter("ttl_evicted_total"), 8);
        assert_eq!(metrics.gauge("freshness_sla_violations"), Some(1.0));
        assert_eq!(metrics.gauge("ttl_last_sweep_at"), Some(1_100.0));
    }

    #[test]
    fn background_thread_sweeps_on_its_own() {
        let (online, freshness, metrics) = setup();
        let clock = Clock::fixed(2_000); // everything written at 1000 has expired
        let sweeper = TtlSweeper::spawn(
            online.clone(),
            freshness.clone(),
            metrics.clone(),
            clock,
            Duration::from_millis(2),
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while !online.is_empty() && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(online.len(), 0, "background sweeper must reclaim expired entries");
        assert_eq!(sweeper.total_evicted(), 8);
        assert!(sweeper.sweeps() >= 1);
        drop(sweeper); // must stop promptly without hanging the test
    }
}
