//! Data staleness / freshness SLA metric (§2.1): "how fresh or latest is
//! the feature data computed by the platform".
//!
//! Freshness of a feature-set table at processing time `now` is
//!
//! ```text
//! staleness = now − source_delay − materialized_high_water
//! ```
//!
//! i.e. how much *ripe* event time is not yet materialized.  A table is
//! within SLA when staleness ≤ the configured bound (typically one
//! schedule interval).

use std::collections::HashMap;
use std::sync::Mutex;

use crate::types::{Timestamp};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Freshness {
    /// Materialized event-time high-water mark.
    pub high_water: Timestamp,
    /// Seconds of ripe-but-unmaterialized event time.
    pub staleness_secs: i64,
    pub within_sla: bool,
}

#[derive(Debug, Clone, Copy)]
struct TableState {
    high_water: Timestamp,
    source_delay: i64,
    sla_bound: i64,
}

/// Tracks per-table freshness against SLA bounds.
#[derive(Debug, Default)]
pub struct FreshnessTracker {
    tables: Mutex<HashMap<String, TableState>>,
}

impl FreshnessTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register/replace a table's SLA parameters.
    pub fn configure(&self, table: &str, source_delay: i64, sla_bound: i64) {
        let mut g = self.tables.lock().unwrap();
        let e = g
            .entry(table.to_string())
            .or_insert(TableState { high_water: i64::MIN, source_delay, sla_bound });
        e.source_delay = source_delay;
        e.sla_bound = sla_bound;
    }

    /// Record materialization progress (monotonic).
    pub fn advance(&self, table: &str, high_water: Timestamp) {
        let mut g = self.tables.lock().unwrap();
        if let Some(s) = g.get_mut(table) {
            s.high_water = s.high_water.max(high_water);
        }
    }

    pub fn freshness(&self, table: &str, now: Timestamp) -> Option<Freshness> {
        let g = self.tables.lock().unwrap();
        let s = g.get(table)?;
        if s.high_water == i64::MIN {
            return Some(Freshness {
                high_water: i64::MIN,
                staleness_secs: i64::MAX,
                within_sla: false,
            });
        }
        let ripe_until = now - s.source_delay;
        let staleness = (ripe_until - s.high_water).max(0);
        Some(Freshness {
            high_water: s.high_water,
            staleness_secs: staleness,
            within_sla: staleness <= s.sla_bound,
        })
    }

    /// Tables currently violating their freshness SLA.
    pub fn violations(&self, now: Timestamp) -> Vec<String> {
        let g = self.tables.lock().unwrap();
        let mut out: Vec<String> = g
            .keys()
            .filter(|t| {
                // Re-borrow through freshness to reuse the logic.
                let s = g[*t];
                if s.high_water == i64::MIN {
                    return true;
                }
                (now - s.source_delay - s.high_water).max(0) > s.sla_bound
            })
            .cloned()
            .collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::time::{DAY, HOUR};

    #[test]
    fn staleness_math() {
        let f = FreshnessTracker::new();
        f.configure("t", 0, DAY);
        f.advance("t", 10 * DAY);
        let fr = f.freshness("t", 10 * DAY + HOUR).unwrap();
        assert_eq!(fr.staleness_secs, HOUR);
        assert!(fr.within_sla);
        let fr = f.freshness("t", 12 * DAY).unwrap();
        assert_eq!(fr.staleness_secs, 2 * DAY);
        assert!(!fr.within_sla);
    }

    #[test]
    fn source_delay_excluded_from_staleness() {
        let f = FreshnessTracker::new();
        f.configure("t", 2 * HOUR, HOUR);
        f.advance("t", DAY);
        // now = DAY + 2h: ripe until DAY → staleness 0.
        let fr = f.freshness("t", DAY + 2 * HOUR).unwrap();
        assert_eq!(fr.staleness_secs, 0);
    }

    #[test]
    fn never_materialized_violates() {
        let f = FreshnessTracker::new();
        f.configure("t", 0, DAY);
        let fr = f.freshness("t", 100).unwrap();
        assert!(!fr.within_sla);
        assert_eq!(f.violations(100), vec!["t".to_string()]);
    }

    #[test]
    fn advance_is_monotonic() {
        let f = FreshnessTracker::new();
        f.configure("t", 0, DAY);
        f.advance("t", 5 * DAY);
        f.advance("t", 3 * DAY); // stale update ignored
        assert_eq!(f.freshness("t", 6 * DAY).unwrap().high_water, 5 * DAY);
    }

    #[test]
    fn unknown_table_none() {
        let f = FreshnessTracker::new();
        assert!(f.freshness("nope", 0).is_none());
    }

    #[test]
    fn violations_sorted_and_filtered() {
        let f = FreshnessTracker::new();
        f.configure("b", 0, HOUR);
        f.configure("a", 0, HOUR);
        f.advance("a", DAY);
        f.advance("b", DAY);
        assert!(f.violations(DAY).is_empty());
        assert_eq!(f.violations(DAY + 2 * HOUR), vec!["a".to_string(), "b".to_string()]);
    }
}
