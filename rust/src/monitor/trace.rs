//! Sampled end-to-end request tracing with a slow-op log.
//!
//! A [`Tracer`] makes a 1-in-N sampling decision per request
//! ([`Tracer::maybe_trace`]). The decision is a per-thread tick — an
//! unsampled request touches **zero atomics** and allocates nothing, so
//! leaving tracing wired in (even switched off) costs a branch on the
//! serving hot path. A sampled request gets a [`TraceContext`]: a small
//! span tree the instrumented layers append to as the request flows
//! through routing, admission, store reads, the PIT join, stream polls
//! and the background drivers. The context itself uses a `Mutex` — that
//! is fine, it only exists on the sampled path.
//!
//! Completed traces land in a bounded lock-free ring (old entries are
//! evicted by overwrite); traces whose total latency crosses
//! `slow_threshold_us` additionally land in a second ring surfaced as
//! `FeatureStore::slow_ops()` and rendered by the load-harness report.

use std::cell::Cell;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Tracing knobs (wired through `coordinator::OpenOptions`).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Sample 1 request in N per thread. `0` disables tracing entirely,
    /// `1` traces every request.
    pub sample_every: u32,
    /// Completed traces at or over this total duration also land in the
    /// slow-op ring.
    pub slow_threshold_us: u64,
    /// Capacity of the completed-trace and slow-op rings.
    pub ring_capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { sample_every: 0, slow_threshold_us: 50_000, ring_capacity: 64 }
    }
}

static NEXT_TRACER_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// (tracer id, tick) — a single-entry per-thread cache. Sampling is
    /// deterministic per (thread, tracer): the first request on a thread
    /// is tick 1, and every `sample_every`-th tick samples. One tracer
    /// per process is the normal shape (the store's); a thread
    /// alternating between tracers resets the tick, which only ever
    /// over-samples.
    static SAMPLE_TICK: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// Process-wide trace collector. Cheap to share (`Arc`) and cheap to
/// consult — see the module docs for the sampling cost model.
pub struct Tracer {
    id: u64,
    cfg: TraceConfig,
    seq: AtomicU64,
    completed: TraceRing,
    slow: TraceRing,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Arc<Tracer> {
        let cap = cfg.ring_capacity.max(1);
        Arc::new(Tracer {
            id: NEXT_TRACER_ID.fetch_add(1, Ordering::Relaxed),
            seq: AtomicU64::new(0),
            completed: TraceRing::new(cap),
            slow: TraceRing::new(cap),
            cfg,
        })
    }

    /// A tracer that never samples (the default when nothing is wired).
    pub fn disabled() -> Arc<Tracer> {
        Self::new(TraceConfig::default())
    }

    pub fn config(&self) -> &TraceConfig {
        &self.cfg
    }

    /// The per-request sampling decision. Off (`sample_every == 0`) is a
    /// single field compare; an unsampled request additionally bumps one
    /// thread-local tick. Neither touches an atomic or allocates.
    pub fn maybe_trace(self: &Arc<Self>, op: &str) -> Option<Arc<TraceContext>> {
        let n = self.cfg.sample_every;
        if n == 0 {
            return None;
        }
        if n > 1 {
            let sampled = SAMPLE_TICK.with(|c| {
                let (id, tick) = c.get();
                let tick = if id == self.id { tick.wrapping_add(1) } else { 1 };
                c.set((self.id, tick));
                tick % n as u64 == 0
            });
            if !sampled {
                return None;
            }
        }
        Some(Arc::new(TraceContext {
            op: op.to_string(),
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            started: Instant::now(),
            inner: Mutex::new(TraceInner { spans: Vec::new(), stack: Vec::new(), finished: false }),
            tracer: self.clone(),
        }))
    }

    /// Drain the completed-trace ring (oldest first).
    pub fn recent(&self) -> Vec<Arc<CompletedTrace>> {
        self.completed.drain()
    }

    /// Drain the slow-op ring (oldest first).
    pub fn slow_ops(&self) -> Vec<Arc<CompletedTrace>> {
        self.slow.drain()
    }
}

/// One span in a trace: `dur_us == 0` entries are point events.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: String,
    pub detail: String,
    /// Microseconds since the trace started.
    pub start_us: u64,
    pub dur_us: u64,
    /// Nesting depth under the request root.
    pub depth: u32,
}

struct TraceInner {
    spans: Vec<Span>,
    /// Indices of currently-open spans (for depth assignment).
    stack: Vec<usize>,
    finished: bool,
}

/// A sampled in-flight request. Share it (`Arc`) with fan-out workers;
/// they append point events with [`TraceContext::event`].
pub struct TraceContext {
    op: String,
    seq: u64,
    started: Instant,
    inner: Mutex<TraceInner>,
    tracer: Arc<Tracer>,
}

impl TraceContext {
    fn now_us(&self) -> u64 {
        self.started.elapsed().as_micros() as u64
    }

    /// Open a timed span; it closes (and records its duration) when the
    /// returned guard drops.
    pub fn span(&self, name: &str) -> SpanGuard<'_> {
        let start_us = self.now_us();
        let mut g = self.inner.lock().unwrap();
        let depth = g.stack.len() as u32;
        let idx = g.spans.len();
        g.spans.push(Span {
            name: name.to_string(),
            detail: String::new(),
            start_us,
            dur_us: 0,
            depth,
        });
        g.stack.push(idx);
        SpanGuard { ctx: self, idx }
    }

    /// Record a point event (zero duration) at the current depth. Safe
    /// to call from worker threads holding a clone of the context.
    pub fn event(&self, name: &str, detail: String) {
        let start_us = self.now_us();
        let mut g = self.inner.lock().unwrap();
        let depth = g.stack.len() as u32;
        g.spans.push(Span { name: name.to_string(), detail, start_us, dur_us: 0, depth });
    }

    /// Close the trace: freeze the span tree, stamp the total latency,
    /// and publish into the completed ring (and the slow-op ring if over
    /// threshold). Idempotent; later calls are no-ops.
    pub fn finish(&self) {
        let total_us = self.now_us();
        let spans = {
            let mut g = self.inner.lock().unwrap();
            if g.finished {
                return;
            }
            g.finished = true;
            g.stack.clear();
            std::mem::take(&mut g.spans)
        };
        let done =
            Arc::new(CompletedTrace { op: self.op.clone(), seq: self.seq, total_us, spans });
        if total_us >= self.tracer.cfg.slow_threshold_us {
            self.tracer.slow.push(done.clone());
        }
        self.tracer.completed.push(done);
    }
}

/// RAII guard for a timed span.
pub struct SpanGuard<'a> {
    ctx: &'a TraceContext,
    idx: usize,
}

impl SpanGuard<'_> {
    /// Attach/replace the span's detail string.
    pub fn note(&self, detail: String) {
        let mut g = self.ctx.inner.lock().unwrap();
        let idx = self.idx;
        if let Some(s) = g.spans.get_mut(idx) {
            s.detail = detail;
        }
    }
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let end = self.ctx.now_us();
        let mut g = self.ctx.inner.lock().unwrap();
        if let Some(pos) = g.stack.iter().rposition(|&i| i == self.idx) {
            g.stack.remove(pos);
        }
        if let Some(s) = g.spans.get_mut(self.idx) {
            s.dur_us = end.saturating_sub(s.start_us);
        }
    }
}

/// A finished trace: the full span tree plus total latency.
#[derive(Debug)]
pub struct CompletedTrace {
    pub op: String,
    pub seq: u64,
    pub total_us: u64,
    pub spans: Vec<Span>,
}

impl CompletedTrace {
    /// Human-readable indented span tree, one line per span.
    pub fn render(&self) -> String {
        let mut out = format!("[{}#{}] total={}µs\n", self.op, self.seq, self.total_us);
        for s in &self.spans {
            let indent = "  ".repeat(s.depth as usize + 1);
            out.push_str(&format!(
                "{indent}{} +{}µs ({}µs) {}\n",
                s.name, s.start_us, s.dur_us, s.detail
            ));
        }
        out
    }
}

/// Bounded lock-free MPMC ring of completed traces. A writer claims a
/// slot by bumping the wrapping cursor and `swap`s its trace in; the
/// displaced occupant (if any) is dropped by that writer — that is the
/// eviction policy. `drain` swaps every slot empty. A slot pointer is
/// only ever dereferenced by whoever `swap`ed it out, which transfers
/// exclusive ownership, so there is no use-after-free or ABA hazard.
struct TraceRing {
    slots: Vec<AtomicPtr<CompletedTrace>>,
    cursor: AtomicUsize,
}

impl TraceRing {
    fn new(cap: usize) -> TraceRing {
        TraceRing {
            slots: (0..cap).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
            cursor: AtomicUsize::new(0),
        }
    }

    fn push(&self, t: Arc<CompletedTrace>) {
        let i = self.cursor.fetch_add(1, Ordering::Relaxed) % self.slots.len();
        let old = self.slots[i].swap(Arc::into_raw(t).cast_mut(), Ordering::AcqRel);
        if !old.is_null() {
            // Safety: the swap handed us exclusive ownership of `old`.
            unsafe { drop(Arc::from_raw(old)) };
        }
    }

    /// Destructive read of every occupied slot, oldest first.
    fn drain(&self) -> Vec<Arc<CompletedTrace>> {
        let mut out: Vec<Arc<CompletedTrace>> = Vec::new();
        for s in &self.slots {
            let p = s.swap(std::ptr::null_mut(), Ordering::AcqRel);
            if !p.is_null() {
                // Safety: as in `push` — the swap transferred ownership.
                out.push(unsafe { Arc::from_raw(p) });
            }
        }
        out.sort_by_key(|t| t.seq);
        out
    }
}

impl Drop for TraceRing {
    fn drop(&mut self) {
        let _ = self.drain();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seq: u64) -> Arc<CompletedTrace> {
        Arc::new(CompletedTrace { op: "x".into(), seq, total_us: 0, spans: Vec::new() })
    }

    #[test]
    fn ring_bounded_with_oldest_evicted_first() {
        let ring = TraceRing::new(4);
        for i in 0..6 {
            ring.push(trace(i));
        }
        // Capacity 4, 6 pushes: seq 0 and 1 were overwritten (oldest
        // first); the survivors drain in order.
        let seqs: Vec<u64> = ring.drain().iter().map(|t| t.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4, 5]);
        assert!(ring.drain().is_empty());
    }

    #[test]
    fn sampling_is_deterministic_per_thread() {
        let t = Tracer::new(TraceConfig {
            sample_every: 4,
            slow_threshold_us: u64::MAX,
            ring_capacity: 64,
        });
        let mut sampled = Vec::new();
        for i in 0..16 {
            if let Some(tc) = t.maybe_trace("op") {
                sampled.push(i);
                tc.finish();
            }
        }
        // A fresh tracer always starts this thread's tick at 1, so
        // exactly every 4th request samples: indices 3, 7, 11, 15.
        assert_eq!(sampled, vec![3, 7, 11, 15]);
        assert_eq!(t.recent().len(), 4);
    }

    #[test]
    fn off_and_always_modes() {
        let off = Tracer::new(TraceConfig { sample_every: 0, ..Default::default() });
        assert!(off.maybe_trace("op").is_none());
        let always = Tracer::new(TraceConfig {
            sample_every: 1,
            slow_threshold_us: u64::MAX,
            ring_capacity: 8,
        });
        assert!(always.maybe_trace("op").is_some());
        assert!(always.maybe_trace("op").is_some());
    }

    #[test]
    fn slow_ops_capture_full_span_tree() {
        let t = Tracer::new(TraceConfig {
            sample_every: 1,
            slow_threshold_us: 0, // everything is "slow"
            ring_capacity: 8,
        });
        let tc = t.maybe_trace("online_read").unwrap();
        {
            let g = tc.span("route");
            g.note("mech=local staleness=0s".into());
            tc.event("store_read", "keys=3 hits=2".into());
        }
        tc.finish();
        tc.finish(); // idempotent
        let slow = t.slow_ops();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].spans.len(), 2);
        assert_eq!(slow[0].spans[0].depth, 0);
        assert_eq!(slow[0].spans[1].depth, 1); // event nested under the open span
        let r = slow[0].render();
        assert!(r.contains("route") && r.contains("mech=local") && r.contains("keys=3"), "{r}");
        // finish() also placed it in the completed ring exactly once.
        assert_eq!(t.recent().len(), 1);
    }

    #[test]
    fn unsampled_requests_record_nothing() {
        let t = Tracer::new(TraceConfig {
            sample_every: 1000,
            slow_threshold_us: 0,
            ring_capacity: 8,
        });
        for _ in 0..10 {
            assert!(t.maybe_trace("op").is_none());
        }
        assert!(t.recent().is_empty());
        assert!(t.slow_ops().is_empty());
    }
}
