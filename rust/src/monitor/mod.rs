//! Health / monitoring subsystem (§3.1.2) and freshness SLA metric
//! (§2.1 "Data Staleness/Freshness").
//!
//! Metrics are classified **built-in (system)** vs **custom (user
//! defined)**, as the paper specifies; system metrics back the SLA
//! machinery, custom metrics surface the customer's feature-engineering
//! insight.
//!
//! Layout:
//!
//! * [`metrics`] — the lock-free metrics core: striped-atomic counters,
//!   per-thread-striped histograms, typed hot-path handles, a
//!   string-keyed compat shim, Prometheus `export()`, and the diffable
//!   [`metrics::MetricsSnapshot`] the load harness embeds per phase in
//!   `BENCH_load.json`.
//! * [`names`] — the canonical metric-name vocabulary shared by every
//!   driver, plus builders for dynamic-suffix names.
//! * [`trace`] — sampled end-to-end request tracing: 1-in-N
//!   [`trace::TraceContext`] span trees (zero atomics when unsampled)
//!   collected into bounded lock-free rings, with a slow-op ring
//!   surfaced as `FeatureStore::slow_ops()`.
//! * [`freshness`] / [`sweeper`] — the staleness SLA tracker and the TTL
//!   sweeper that feeds it.

pub mod freshness;
pub mod metrics;
pub mod names;
pub mod sweeper;
pub mod trace;

pub use freshness::FreshnessTracker;
pub use metrics::{Counter, Gauge, LatencyHandle, MetricKind, MetricsRegistry, MetricsSnapshot};
pub use sweeper::{sweep_once, SweepReport, TtlSweeper};
pub use trace::{CompletedTrace, TraceConfig, TraceContext, Tracer};
