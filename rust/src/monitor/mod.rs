//! Health / monitoring subsystem (§3.1.2) and freshness SLA metric
//! (§2.1 "Data Staleness/Freshness").
//!
//! Metrics are classified **built-in (system)** vs **custom (user
//! defined)**, as the paper specifies; system metrics back the SLA
//! machinery, custom metrics surface the customer's feature-engineering
//! insight.

pub mod freshness;
pub mod metrics;
pub mod sweeper;

pub use freshness::FreshnessTracker;
pub use metrics::{MetricKind, MetricsRegistry};
pub use sweeper::{sweep_once, SweepReport, TtlSweeper};
