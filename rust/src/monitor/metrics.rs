//! Metrics registry: counters, gauges and latency histograms, tagged
//! system vs custom (§3.1.2).
//!
//! Built for the hot path. Counters are striped `AtomicU64`s (one
//! cache-padded stripe per thread slot, folded on read) and latency
//! metrics are per-thread-striped atomic histograms — each serving
//! thread records into its own `AtomicU64` bucket array mirroring
//! `util::hist::Histogram`'s layout, and readers fold the stripes into
//! one `Histogram` on demand. `inc` / `observe` through a pre-registered
//! typed handle ([`Counter`], [`Gauge`], [`LatencyHandle`]) is a couple
//! of relaxed atomic RMWs: no `Mutex`, no `RwLock`, no allocation.
//!
//! The string-keyed dynamic API (`inc(kind, name, by)` etc.) survives as
//! a compat shim: the name index is an immutable `BTreeMap` snapshot
//! behind an `AtomicPtr` (hand-rolled RCU), so the lookup is one atomic
//! pointer load plus a map probe — also lock-free and allocation-free.
//! Only first-touch registration takes the writer mutex: it clones the
//! map, inserts, publishes the new snapshot, and parks the old one until
//! `Drop` (readers may still be holding borrows into it).
//!
//! Read-side views: [`MetricsRegistry::render`] (human dashboard),
//! [`MetricsRegistry::export`] (Prometheus text exposition), and
//! [`MetricsRegistry::snapshot`] — a diffable [`MetricsSnapshot`] used by
//! the load harness to embed per-phase metric deltas in `BENCH_load.json`.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::hist::{Histogram, BUCKETS};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Built-in: drives HA/SLA machinery.
    System,
    /// User-defined: customer insight into their feature pipelines.
    Custom,
}

impl MetricKind {
    fn label(self) -> &'static str {
        match self {
            MetricKind::System => "system",
            MetricKind::Custom => "custom",
        }
    }
}

// ---- thread striping -------------------------------------------------------

/// Stripes per counter. Power of two so the slot fold is a mask.
const COUNTER_STRIPES: usize = 8;
/// Stripes per latency histogram. Each stripe is a full atomic bucket
/// array (~32 KiB), so keep this small; four absorbs the contention that
/// matters without bloating per-metric memory.
const HIST_STRIPES: usize = 4;

static NEXT_THREAD_SLOT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Stable per-thread slot; assigned once per thread (one global
/// `fetch_add`), then a plain thread-local read.
#[inline]
fn thread_slot() -> usize {
    THREAD_SLOT.with(|s| {
        let v = s.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT_THREAD_SLOT.fetch_add(1, Ordering::Relaxed);
        s.set(v);
        v
    })
}

#[repr(align(64))]
struct PaddedU64(AtomicU64);

// ---- metric cores ----------------------------------------------------------

struct CounterCore {
    stripes: [PaddedU64; COUNTER_STRIPES],
}

impl CounterCore {
    fn new() -> Self {
        CounterCore { stripes: std::array::from_fn(|_| PaddedU64(AtomicU64::new(0))) }
    }

    #[inline]
    fn add(&self, by: u64) {
        self.stripes[thread_slot() & (COUNTER_STRIPES - 1)].0.fetch_add(by, Ordering::Relaxed);
    }

    fn value(&self) -> u64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

struct GaugeCore {
    bits: AtomicU64,
    /// 0 = never set; lets `gauge()` keep returning `None` for
    /// pre-registered gauges nothing has written yet.
    writes: AtomicU64,
}

impl GaugeCore {
    fn new() -> Self {
        GaugeCore { bits: AtomicU64::new(0), writes: AtomicU64::new(0) }
    }

    #[inline]
    fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
        self.writes.fetch_add(1, Ordering::Release);
    }

    fn get(&self) -> Option<f64> {
        if self.writes.load(Ordering::Acquire) == 0 {
            None
        } else {
            Some(f64::from_bits(self.bits.load(Ordering::Relaxed)))
        }
    }
}

/// One stripe of an atomic histogram: bucket counts in the exact
/// `Histogram` layout plus the scalar accumulators `fold` needs.
struct HistStripe {
    counts: Box<[AtomicU64]>,
    sum: AtomicU64, // nanoseconds; u64 holds ~584 years of summed ns
    min: AtomicU64,
    max: AtomicU64,
}

impl HistStripe {
    fn new() -> Self {
        HistStripe {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

struct LatencyCore {
    stripes: Vec<HistStripe>,
}

impl LatencyCore {
    fn new() -> Self {
        LatencyCore { stripes: (0..HIST_STRIPES).map(|_| HistStripe::new()).collect() }
    }

    #[inline]
    fn observe(&self, nanos: u64) {
        let s = &self.stripes[thread_slot() % HIST_STRIPES];
        s.counts[Histogram::index_of(nanos)].fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(nanos, Ordering::Relaxed);
        s.min.fetch_min(nanos, Ordering::Relaxed);
        s.max.fetch_max(nanos, Ordering::Relaxed);
    }

    /// Fold every stripe into one `Histogram` (read side only).
    fn fold(&self) -> Histogram {
        let mut counts = vec![0u64; BUCKETS];
        let mut sum = 0u128;
        let mut min = u64::MAX;
        let mut max = 0u64;
        for s in &self.stripes {
            for (acc, c) in counts.iter_mut().zip(s.counts.iter()) {
                *acc += c.load(Ordering::Relaxed);
            }
            sum += s.sum.load(Ordering::Relaxed) as u128;
            min = min.min(s.min.load(Ordering::Relaxed));
            max = max.max(s.max.load(Ordering::Relaxed));
        }
        Histogram::from_parts(counts, sum, min, max)
    }
}

// ---- typed handles ---------------------------------------------------------

/// Pre-registered counter handle: `inc` is one relaxed `fetch_add` on a
/// thread-striped cell — no lock, no name lookup, no allocation.
#[derive(Clone)]
pub struct Counter {
    core: Arc<CounterCore>,
}

impl Counter {
    #[inline]
    pub fn inc(&self, by: u64) {
        self.core.add(by);
    }

    pub fn value(&self) -> u64 {
        self.core.value()
    }
}

/// Pre-registered gauge handle (last-writer-wins level).
#[derive(Clone)]
pub struct Gauge {
    core: Arc<GaugeCore>,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        self.core.set(v);
    }

    pub fn value(&self) -> Option<f64> {
        self.core.get()
    }
}

/// Pre-registered latency handle: `observe` records into the calling
/// thread's histogram stripe — a handful of relaxed atomic RMWs.
#[derive(Clone)]
pub struct LatencyHandle {
    core: Arc<LatencyCore>,
}

impl LatencyHandle {
    #[inline]
    pub fn observe(&self, nanos: u64) {
        self.core.observe(nanos);
    }

    pub fn quantile(&self, q: f64) -> u64 {
        self.core.fold().quantile(q)
    }

    /// Folded snapshot of all stripes.
    pub fn histogram(&self) -> Histogram {
        self.core.fold()
    }
}

#[derive(Clone)]
enum Slot {
    Counter(Arc<CounterCore>),
    Gauge(Arc<GaugeCore>),
    Latency(Arc<LatencyCore>),
}

impl Slot {
    fn type_name(&self) -> &'static str {
        match self {
            Slot::Counter(_) => "counter",
            Slot::Gauge(_) => "gauge",
            Slot::Latency(_) => "latency",
        }
    }
}

type Index = BTreeMap<String, (MetricKind, Slot)>;

// ---- registry --------------------------------------------------------------

/// Central metrics store. See the module docs for the concurrency
/// design; the short version is that everything a request does is
/// lock-free and only first-touch name registration serializes.
pub struct MetricsRegistry {
    /// Immutable name-index snapshot (RCU). Readers load + probe;
    /// never a lock on this path.
    index: AtomicPtr<Index>,
    /// Writer side: serializes registration and parks retired snapshots
    /// until `Drop`, because readers may still hold borrows into them.
    writer: Mutex<Vec<*mut Index>>,
}

// Safety: the raw pointers in `index`/`writer` refer to heap `Index`
// maps that are immutable after publication (writers replace, never
// mutate). The retired list is only touched under the writer mutex or
// with `&mut self` in `Drop`, and the map contents (`Arc`-held cores of
// atomics) are themselves `Send + Sync`.
unsafe impl Send for MetricsRegistry {}
unsafe impl Sync for MetricsRegistry {}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MetricsRegistry({} metrics)", self.index_ref().len())
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            index: AtomicPtr::new(Box::into_raw(Box::default())),
            writer: Mutex::new(Vec::new()),
        }
    }

    /// Current index snapshot. Lock-free; valid for the lifetime of
    /// `&self` because retired snapshots are only freed in `Drop`.
    #[inline]
    fn index_ref(&self) -> &Index {
        // Safety: see the `Send`/`Sync` impls — published pointers stay
        // live until the registry itself is dropped.
        unsafe { &*self.index.load(Ordering::Acquire) }
    }

    /// Slow path: register `name` if absent, returning whatever slot the
    /// name resolves to afterwards (which may be a pre-existing slot of
    /// a different type — callers warn on mismatch).
    fn register(&self, kind: MetricKind, name: &str, make: impl FnOnce() -> Slot) -> Slot {
        let mut retired = self.writer.lock().unwrap();
        // Re-check under the writer lock: another thread may have won.
        let cur = self.index_ref();
        if let Some((_, slot)) = cur.get(name) {
            return slot.clone();
        }
        let slot = make();
        let mut next = cur.clone();
        next.insert(name.to_string(), (kind, slot.clone()));
        let old = self.index.swap(Box::into_raw(Box::new(next)), Ordering::AcqRel);
        retired.push(old);
        slot
    }

    fn slot_for(&self, kind: MetricKind, name: &str, make: impl FnOnce() -> Slot) -> Slot {
        // Fast path: lock-free probe of the published snapshot.
        if let Some((_, slot)) = self.index_ref().get(name) {
            return slot.clone();
        }
        self.register(kind, name, make)
    }

    // ---- typed handle registration ------------------------------------

    /// Pre-register (or look up) a counter and return its hot-path
    /// handle. On a type clash the existing metric is left untouched and
    /// a detached handle is returned (observations go nowhere).
    pub fn counter_handle(&self, kind: MetricKind, name: &str) -> Counter {
        match self.slot_for(kind, name, || Slot::Counter(Arc::new(CounterCore::new()))) {
            Slot::Counter(core) => Counter { core },
            other => {
                log::warn!("metric '{name}' is a {}, not a counter", other.type_name());
                Counter { core: Arc::new(CounterCore::new()) }
            }
        }
    }

    /// Pre-register (or look up) a gauge handle.
    pub fn gauge_handle(&self, kind: MetricKind, name: &str) -> Gauge {
        match self.slot_for(kind, name, || Slot::Gauge(Arc::new(GaugeCore::new()))) {
            Slot::Gauge(core) => Gauge { core },
            other => {
                log::warn!("metric '{name}' is a {}, not a gauge", other.type_name());
                Gauge { core: Arc::new(GaugeCore::new()) }
            }
        }
    }

    /// Pre-register (or look up) a latency handle.
    pub fn latency_handle(&self, kind: MetricKind, name: &str) -> LatencyHandle {
        match self.slot_for(kind, name, || Slot::Latency(Arc::new(LatencyCore::new()))) {
            Slot::Latency(core) => LatencyHandle { core },
            other => {
                log::warn!("metric '{name}' is a {}, not a latency", other.type_name());
                LatencyHandle { core: Arc::new(LatencyCore::new()) }
            }
        }
    }

    // ---- string-keyed compat shim -------------------------------------

    pub fn inc(&self, kind: MetricKind, name: &str, by: u64) {
        match self.slot_for(kind, name, || Slot::Counter(Arc::new(CounterCore::new()))) {
            Slot::Counter(c) => c.add(by),
            _ => log::warn!("metric '{name}' is not a counter"),
        }
    }

    pub fn set_gauge(&self, kind: MetricKind, name: &str, value: f64) {
        match self.slot_for(kind, name, || Slot::Gauge(Arc::new(GaugeCore::new()))) {
            Slot::Gauge(g) => g.set(value),
            // Refuse to clobber an existing counter/latency of the same
            // name — consistent with `inc`/`observe_latency`.
            _ => log::warn!("metric '{name}' is not a gauge"),
        }
    }

    pub fn observe_latency(&self, kind: MetricKind, name: &str, nanos: u64) {
        match self.slot_for(kind, name, || Slot::Latency(Arc::new(LatencyCore::new()))) {
            Slot::Latency(h) => h.observe(nanos),
            _ => log::warn!("metric '{name}' is not a latency"),
        }
    }

    // ---- readers -------------------------------------------------------

    pub fn counter(&self, name: &str) -> u64 {
        match self.index_ref().get(name) {
            Some((_, Slot::Counter(c))) => c.value(),
            _ => 0,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        match self.index_ref().get(name) {
            Some((_, Slot::Gauge(g))) => g.get(),
            _ => None,
        }
    }

    pub fn latency_quantile(&self, name: &str, q: f64) -> Option<u64> {
        match self.index_ref().get(name) {
            Some((_, Slot::Latency(h))) => Some(h.fold().quantile(q)),
            _ => None,
        }
    }

    /// Render all metrics of a kind (dashboard / `geofs metrics`).
    pub fn render(&self, kind: Option<MetricKind>) -> String {
        let mut out = String::new();
        for (name, (k, slot)) in self.index_ref().iter() {
            if kind.is_some() && kind != Some(*k) {
                continue;
            }
            let tag = k.label();
            match slot {
                Slot::Counter(c) => out.push_str(&format!("{name}{{{tag}}} = {}\n", c.value())),
                Slot::Gauge(g) => {
                    let v = g.get().unwrap_or(0.0);
                    out.push_str(&format!("{name}{{{tag}}} = {v:.3}\n"));
                }
                Slot::Latency(h) => out
                    .push_str(&format!("{name}{{{tag}}} {}\n", h.fold().summary(1_000.0, "µs"))),
            }
        }
        out
    }

    /// Prometheus text exposition: `# TYPE` line per metric, `kind`
    /// label, quantile series + `_count`/`_sum` for latencies.
    pub fn export(&self) -> String {
        let mut out = String::new();
        for (name, (k, slot)) in self.index_ref().iter() {
            let kind = k.label();
            match slot {
                Slot::Counter(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n"));
                    out.push_str(&format!("{name}{{kind=\"{kind}\"}} {}\n", c.value()));
                }
                Slot::Gauge(g) => {
                    out.push_str(&format!("# TYPE {name} gauge\n"));
                    out.push_str(&format!("{name}{{kind=\"{kind}\"}} {}\n", g.get().unwrap_or(0.0)));
                }
                Slot::Latency(l) => {
                    let h = l.fold();
                    out.push_str(&format!("# TYPE {name} summary\n"));
                    for q in [0.5, 0.95, 0.99, 0.999] {
                        out.push_str(&format!(
                            "{name}{{kind=\"{kind}\",quantile=\"{q}\"}} {}\n",
                            h.quantile(q)
                        ));
                    }
                    out.push_str(&format!("{name}_count{{kind=\"{kind}\"}} {}\n", h.count()));
                    out.push_str(&format!("{name}_sum{{kind=\"{kind}\"}} {}\n", h.sum()));
                }
            }
        }
        out
    }

    /// Point-in-time snapshot of every metric, diffable via
    /// [`MetricsSnapshot::delta`] and serializable via
    /// [`MetricsSnapshot::to_json`].
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        for (name, (_, slot)) in self.index_ref().iter() {
            match slot {
                Slot::Counter(c) => {
                    snap.counters.insert(name.clone(), c.value());
                }
                Slot::Gauge(g) => {
                    if let Some(v) = g.get() {
                        snap.gauges.insert(name.clone(), v);
                    }
                }
                Slot::Latency(l) => {
                    let h = l.fold();
                    snap.latencies.insert(
                        name.clone(),
                        LatencySnapshot {
                            count: h.count(),
                            mean_ns: h.mean(),
                            p50_ns: h.quantile(0.5),
                            p99_ns: h.quantile(0.99),
                            max_ns: h.max(),
                        },
                    );
                }
            }
        }
        snap
    }
}

impl Drop for MetricsRegistry {
    fn drop(&mut self) {
        // Nobody can hold borrows anymore (`&mut self`): free the
        // current snapshot and every retired one.
        let retired = self.writer.get_mut().unwrap();
        for p in retired.drain(..) {
            // Safety: retired pointers were uniquely parked here.
            unsafe { drop(Box::from_raw(p)) };
        }
        let cur = *self.index.get_mut();
        // Safety: the published pointer is exclusively ours now.
        unsafe { drop(Box::from_raw(cur)) };
    }
}

// ---- snapshots -------------------------------------------------------------

/// Latency digest inside a [`MetricsSnapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

/// A diffable view of the whole registry at one instant. `delta`
/// subtracts cumulative quantities (counter values, latency counts)
/// while levels (gauges) and distribution digests keep the later
/// snapshot's value — so a per-phase delta reads as "what this phase
/// added, and where the levels ended up".
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub latencies: BTreeMap<String, LatencySnapshot>,
}

impl MetricsSnapshot {
    /// `self - earlier` for cumulative quantities; see the type docs.
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let mut out = self.clone();
        for (name, v) in out.counters.iter_mut() {
            *v = v.saturating_sub(earlier.counters.get(name).copied().unwrap_or(0));
        }
        for (name, l) in out.latencies.iter_mut() {
            let before = earlier.latencies.get(name).map(|e| e.count).unwrap_or(0);
            l.count = l.count.saturating_sub(before);
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
            .collect();
        let gauges = self.gauges.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect();
        let latencies = self
            .latencies
            .iter()
            .map(|(k, l)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::num(l.count as f64)),
                        ("mean_ns", Json::num(l.mean_ns)),
                        ("p50_ns", Json::num(l.p50_ns as f64)),
                        ("p99_ns", Json::num(l.p99_ns as f64)),
                        ("max_ns", Json::num(l.max_ns as f64)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("latencies", Json::Obj(latencies)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "jobs_total", 1);
        m.inc(MetricKind::System, "jobs_total", 2);
        assert_eq!(m.counter("jobs_total"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = MetricsRegistry::new();
        m.set_gauge(MetricKind::Custom, "fill_rate", 0.5);
        m.set_gauge(MetricKind::Custom, "fill_rate", 0.75);
        assert_eq!(m.gauge("fill_rate"), Some(0.75));
    }

    #[test]
    fn latencies_quantile() {
        let m = MetricsRegistry::new();
        for v in [100u64, 200, 300, 400, 1000] {
            m.observe_latency(MetricKind::System, "lookup_ns", v);
        }
        let p50 = m.latency_quantile("lookup_ns", 0.5).unwrap();
        assert!((200..=300).contains(&p50), "p50={p50}");
        assert!(m.latency_quantile("nope", 0.5).is_none());
    }

    #[test]
    fn render_filters_by_kind() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "sys_counter", 1);
        m.set_gauge(MetricKind::Custom, "cust_gauge", 2.0);
        let sys = m.render(Some(MetricKind::System));
        assert!(sys.contains("sys_counter") && !sys.contains("cust_gauge"));
        let all = m.render(None);
        assert!(all.contains("sys_counter") && all.contains("cust_gauge"));
    }

    #[test]
    fn kind_mismatch_is_tolerated() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "x", 1);
        m.observe_latency(MetricKind::System, "x", 5); // wrong type: warn, no panic
        assert_eq!(m.counter("x"), 1);
    }

    #[test]
    fn set_gauge_refuses_type_clash() {
        let m = MetricsRegistry::new();
        // Regression: set_gauge used to silently replace an existing
        // counter/latency of the same name.
        m.inc(MetricKind::System, "y", 7);
        m.set_gauge(MetricKind::System, "y", 1.0); // wrong type: warn, refuse
        assert_eq!(m.counter("y"), 7);
        assert_eq!(m.gauge("y"), None);
        m.observe_latency(MetricKind::System, "lat", 100);
        m.set_gauge(MetricKind::System, "lat", 2.0);
        assert_eq!(m.latency_quantile("lat", 0.5), Some(100));
        // And the reverse: a gauge is not clobbered by inc.
        m.set_gauge(MetricKind::System, "z", 2.0);
        m.inc(MetricKind::System, "z", 1);
        assert_eq!(m.gauge("z"), Some(2.0));
        assert_eq!(m.counter("z"), 0);
    }

    #[test]
    fn typed_handles_share_the_named_metric() {
        let m = MetricsRegistry::new();
        let c = m.counter_handle(MetricKind::System, "h_total");
        c.inc(5);
        m.inc(MetricKind::System, "h_total", 2); // shim hits the same core
        assert_eq!(m.counter("h_total"), 7);
        assert_eq!(c.value(), 7);

        let g = m.gauge_handle(MetricKind::System, "h_gauge");
        assert_eq!(m.gauge("h_gauge"), None); // registered but unset
        g.set(3.5);
        assert_eq!(m.gauge("h_gauge"), Some(3.5));

        let l = m.latency_handle(MetricKind::System, "h_lat");
        l.observe(1_000);
        assert_eq!(m.latency_quantile("h_lat", 1.0), Some(1_000));
        assert_eq!(l.histogram().count(), 1);
    }

    #[test]
    fn multithread_conservation() {
        const THREADS: u64 = 8;
        const OPS: u64 = 10_000;
        let m = Arc::new(MetricsRegistry::new());
        let c = m.counter_handle(MetricKind::System, "ops");
        let l = m.latency_handle(MetricKind::System, "lat_ns");
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let c = c.clone();
                let l = l.clone();
                let m = m.clone();
                s.spawn(move || {
                    for i in 0..OPS {
                        c.inc(1);
                        l.observe(100 + (i % 100));
                        // Hammer the string-keyed shim concurrently too:
                        // its first touch races registration across
                        // threads, the rest take the lock-free path.
                        m.inc(MetricKind::System, "ops_shim", 1);
                    }
                });
            }
        });
        assert_eq!(m.counter("ops"), THREADS * OPS);
        assert_eq!(m.counter("ops_shim"), THREADS * OPS);
        assert_eq!(l.histogram().count(), THREADS * OPS);
        let h = l.histogram();
        assert_eq!(h.min(), 100);
        assert_eq!(h.max(), 199);
    }

    #[test]
    fn export_prometheus_text() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "reqs_total", 3);
        m.set_gauge(MetricKind::Custom, "fill", 0.5);
        m.observe_latency(MetricKind::System, "lat_ns", 1_000);
        let text = m.export();
        assert!(text.contains("# TYPE reqs_total counter"), "{text}");
        assert!(text.contains("reqs_total{kind=\"system\"} 3"), "{text}");
        assert!(text.contains("# TYPE fill gauge"), "{text}");
        assert!(text.contains("fill{kind=\"custom\"} 0.5"), "{text}");
        assert!(text.contains("# TYPE lat_ns summary"), "{text}");
        assert!(text.contains("lat_ns_count{kind=\"system\"} 1"), "{text}");
        assert!(text.contains("lat_ns_sum{kind=\"system\"} 1000"), "{text}");
    }

    #[test]
    fn snapshot_delta_and_json() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "c", 5);
        m.observe_latency(MetricKind::System, "l", 100);
        let before = m.snapshot();
        m.inc(MetricKind::System, "c", 2);
        m.set_gauge(MetricKind::System, "g", 9.0);
        m.observe_latency(MetricKind::System, "l", 200);
        let after = m.snapshot();
        let d = after.delta(&before);
        assert_eq!(d.counters["c"], 2);
        assert_eq!(d.gauges["g"], 9.0);
        assert_eq!(d.latencies["l"].count, 1);
        let js = d.to_json().to_string();
        // Round-trips through the in-tree JSON parser.
        let parsed = Json::parse(&js).unwrap();
        assert_eq!(parsed.get("counters").get("c").as_i64(), Some(2));
        assert_eq!(parsed.get("latencies").get("l").get("count").as_i64(), Some(1));
    }
}
