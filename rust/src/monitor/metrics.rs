//! Metrics registry: counters, gauges and latency histograms, tagged
//! system vs custom (§3.1.2).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::hist::Histogram;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Built-in: drives HA/SLA machinery.
    System,
    /// User-defined: customer insight into their feature pipelines.
    Custom,
}

#[derive(Debug)]
enum Metric {
    Counter(u64),
    Gauge(f64),
    Latency(Histogram),
}

/// Central metrics store. Cheap enough for the hot path (one mutex per
/// registry; the serving layer keeps its own per-shard histograms and
/// folds them in periodically).
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, (MetricKind, Metric)>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, kind: MetricKind, name: &str, by: u64) {
        let mut g = self.metrics.lock().unwrap();
        match g.entry(name.to_string()).or_insert((kind, Metric::Counter(0))) {
            (_, Metric::Counter(c)) => *c += by,
            _ => log::warn!("metric '{name}' is not a counter"),
        }
    }

    pub fn set_gauge(&self, kind: MetricKind, name: &str, value: f64) {
        let mut g = self.metrics.lock().unwrap();
        g.insert(name.to_string(), (kind, Metric::Gauge(value)));
    }

    pub fn observe_latency(&self, kind: MetricKind, name: &str, nanos: u64) {
        let mut g = self.metrics.lock().unwrap();
        match g
            .entry(name.to_string())
            .or_insert((kind, Metric::Latency(Histogram::new())))
        {
            (_, Metric::Latency(h)) => h.record(nanos),
            _ => log::warn!("metric '{name}' is not a latency"),
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        match self.metrics.lock().unwrap().get(name) {
            Some((_, Metric::Counter(c))) => *c,
            _ => 0,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        match self.metrics.lock().unwrap().get(name) {
            Some((_, Metric::Gauge(v))) => Some(*v),
            _ => None,
        }
    }

    pub fn latency_quantile(&self, name: &str, q: f64) -> Option<u64> {
        match self.metrics.lock().unwrap().get(name) {
            Some((_, Metric::Latency(h))) => Some(h.quantile(q)),
            _ => None,
        }
    }

    /// Render all metrics of a kind (dashboard / `geofs metrics`).
    pub fn render(&self, kind: Option<MetricKind>) -> String {
        let g = self.metrics.lock().unwrap();
        let mut out = String::new();
        for (name, (k, m)) in g.iter() {
            if kind.is_some() && kind != Some(*k) {
                continue;
            }
            let tag = match k {
                MetricKind::System => "system",
                MetricKind::Custom => "custom",
            };
            match m {
                Metric::Counter(c) => out.push_str(&format!("{name}{{{tag}}} = {c}\n")),
                Metric::Gauge(v) => out.push_str(&format!("{name}{{{tag}}} = {v:.3}\n")),
                Metric::Latency(h) => {
                    out.push_str(&format!("{name}{{{tag}}} {}\n", h.summary(1_000.0, "µs")))
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "jobs_total", 1);
        m.inc(MetricKind::System, "jobs_total", 2);
        assert_eq!(m.counter("jobs_total"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let m = MetricsRegistry::new();
        m.set_gauge(MetricKind::Custom, "fill_rate", 0.5);
        m.set_gauge(MetricKind::Custom, "fill_rate", 0.75);
        assert_eq!(m.gauge("fill_rate"), Some(0.75));
    }

    #[test]
    fn latencies_quantile() {
        let m = MetricsRegistry::new();
        for v in [100u64, 200, 300, 400, 1000] {
            m.observe_latency(MetricKind::System, "lookup_ns", v);
        }
        let p50 = m.latency_quantile("lookup_ns", 0.5).unwrap();
        assert!((200..=300).contains(&p50), "p50={p50}");
        assert!(m.latency_quantile("nope", 0.5).is_none());
    }

    #[test]
    fn render_filters_by_kind() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "sys_counter", 1);
        m.set_gauge(MetricKind::Custom, "cust_gauge", 2.0);
        let sys = m.render(Some(MetricKind::System));
        assert!(sys.contains("sys_counter") && !sys.contains("cust_gauge"));
        let all = m.render(None);
        assert!(all.contains("sys_counter") && all.contains("cust_gauge"));
    }

    #[test]
    fn kind_mismatch_is_tolerated() {
        let m = MetricsRegistry::new();
        m.inc(MetricKind::System, "x", 1);
        m.observe_latency(MetricKind::System, "x", 5); // wrong type: warn, no panic
        assert_eq!(m.counter("x"), 1);
    }
}
