//! Canonical metric names.
//!
//! Every system metric the drivers publish lives here, so call sites
//! (`geo/replication.rs`, `serving/admission.rs`, `stream/mod.rs`,
//! `offline_store/compact.rs`, `monitor/sweeper.rs`, the coordinator and
//! the serving front end) share one vocabulary and the export
//! completeness test (`tests/observability.rs`) can assert that the
//! Prometheus `export()` view covers all of them. Names with a dynamic
//! suffix (per-region lag, per-tier merges, per-mechanism latency) get a
//! builder function instead of a constant.

// ---- coordinator -----------------------------------------------------------

/// Records written into the online/offline stores by materialization jobs.
pub const MATERIALIZED_RECORDS: &str = "materialized_records";
/// Materialization job executions.
pub const MATERIALIZATION_JOBS: &str = "materialization_jobs";
/// Rows returned by `get_training_frame` (offline PIT reads).
pub const TRAINING_ROWS_SERVED: &str = "training_rows_served";

// ---- geo replication -------------------------------------------------------

/// Worker count the last parallel replication pump fanned out over.
pub const REPL_APPLY_PARALLEL: &str = "repl_apply_parallel";

/// Replica staleness (seconds behind the durable log) for one region.
pub fn repl_lag_secs(region: &str) -> String {
    format!("repl_lag_secs_{region}")
}

/// Unapplied durable-log records for one region.
pub fn repl_backlog(region: &str) -> String {
    format!("repl_backlog_{region}")
}

// ---- offline compaction ----------------------------------------------------

/// Segment merges performed by the compaction driver, all tiers.
pub const COMPACTION_MERGES_TOTAL: &str = "compaction_merges_total";
/// Segments still eligible for compaction after the last drain.
pub const COMPACTION_BACKLOG: &str = "compaction_backlog";

/// Merges performed at one size tier.
pub fn compaction_merges_tier(tier: usize) -> String {
    format!("compaction_merges_tier{tier}")
}

// ---- TTL sweeper / freshness ----------------------------------------------

/// Online records evicted by TTL sweeps.
pub const TTL_EVICTED_TOTAL: &str = "ttl_evicted_total";
/// Tables currently violating their freshness SLA.
pub const FRESHNESS_SLA_VIOLATIONS: &str = "freshness_sla_violations";
/// Timestamp (epoch secs) of the last completed TTL sweep.
pub const TTL_LAST_SWEEP_AT: &str = "ttl_last_sweep_at";

// ---- admission -------------------------------------------------------------

/// Requests currently holding an admission permit.
pub const ADMISSION_INFLIGHT: &str = "admission_inflight";
/// Requests admitted through the gate.
pub const ADMISSION_ADMITTED: &str = "admission_admitted";
/// Requests shed by the gate.
pub const ADMISSION_SHED: &str = "admission_shed";

// ---- serving ---------------------------------------------------------------

/// Point/batch lookups that found a record (per key).
pub const SERVING_HITS: &str = "serving_hits";
/// Point/batch lookups that missed (per key).
pub const SERVING_MISSES: &str = "serving_misses";
/// Batched lookups served.
pub const SERVING_BATCHES: &str = "serving_batches";

/// Point-lookup latency histogram for one access mechanism
/// (`local` / `xregion` / `replica`). Values are nanoseconds.
pub fn serving_latency_us(mech: &str) -> String {
    format!("serving_latency_us_{mech}")
}

/// Batch-lookup latency histogram for one access mechanism.
pub fn serving_batch_latency_us(mech: &str) -> String {
    format!("serving_batch_latency_us_{mech}")
}

// ---- streaming ingestion ---------------------------------------------------

/// Events dropped by stream backpressure shedding.
pub const STREAM_SHED_EVENTS: &str = "stream_shed_events";
/// Events consumed from the stream log.
pub const STREAM_EVENTS_CONSUMED: &str = "stream_events_consumed";
/// Feature records emitted by stream materialization.
pub const STREAM_RECORDS_EMITTED: &str = "stream_records_emitted";
/// Max-min watermark skew across partitions (seconds).
pub const STREAM_WATERMARK_SKEW_SECS: &str = "stream_watermark_skew_secs";
/// Lag from the slowest partition watermark to the clock (seconds).
pub const STREAM_WATERMARK_LAG_SECS: &str = "stream_watermark_lag_secs";

// -- durable WAL (storage::wal) --

/// Completed fsyncs issued by the WAL append path (all policies).
pub const WAL_SYNC_TOTAL: &str = "wal_sync_total";
/// Frames covered per completed WAL sync — the group-commit
/// amortization factor (1 under `PerAppend`'s single appends).
pub const WAL_GROUP_SIZE: &str = "wal_group_size";
/// Appender-observed wait from staging a frame to its covering sync
/// completing, in microseconds (group commit only).
pub const WAL_ACK_WAIT_US: &str = "wal_ack_wait_us";

/// Every constant-named metric above, for completeness assertions.
/// (Dynamic-suffix names are covered by calling their builders with the
/// suffixes a given deployment actually uses.)
pub const ALL_STATIC: &[&str] = &[
    MATERIALIZED_RECORDS,
    MATERIALIZATION_JOBS,
    TRAINING_ROWS_SERVED,
    REPL_APPLY_PARALLEL,
    COMPACTION_MERGES_TOTAL,
    COMPACTION_BACKLOG,
    TTL_EVICTED_TOTAL,
    FRESHNESS_SLA_VIOLATIONS,
    TTL_LAST_SWEEP_AT,
    ADMISSION_INFLIGHT,
    ADMISSION_ADMITTED,
    ADMISSION_SHED,
    SERVING_HITS,
    SERVING_MISSES,
    SERVING_BATCHES,
    STREAM_SHED_EVENTS,
    STREAM_EVENTS_CONSUMED,
    STREAM_RECORDS_EMITTED,
    STREAM_WATERMARK_SKEW_SECS,
    STREAM_WATERMARK_LAG_SECS,
    WAL_SYNC_TOTAL,
    WAL_GROUP_SIZE,
    WAL_ACK_WAIT_US,
];
