//! Configuration system (JSON; serde is unavailable offline).
//!
//! One file configures a deployment: regions + latency links, store
//! sizing, scheduler retry policy, artifact location.  Examples and the
//! CLI construct [`Config`] from a file or use [`Config::default_local`].

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::exec::RetryPolicy;
use crate::geo::topology::GeoTopology;
use crate::types::{FsError, Result};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RegionLink {
    pub from: String,
    pub to: String,
    pub one_way_us: u64,
}

#[derive(Debug, Clone)]
pub struct Config {
    /// Region names; the first is the default home region.
    pub regions: Vec<String>,
    pub links: Vec<RegionLink>,
    /// In-region lookup latency (µs) for the simulator.
    pub local_latency_us: u64,
    /// Online store shard count per region.
    pub online_shards: usize,
    /// Worker threads for the compute pool.
    pub workers: usize,
    /// AOT artifact directory.
    pub artifacts_dir: PathBuf,
    /// Directory for durable offline segments / checkpoints.
    pub data_dir: PathBuf,
    /// Job retry policy.
    pub retry: RetryPolicy,
    /// Geo-replication lag (secs) when replication is enabled.
    pub replication_lag_secs: i64,
    /// Deterministic seed for synthetic workloads.
    pub seed: u64,
}

impl Config {
    /// Single-region local development ("one box" mode, §2.1).
    pub fn default_local() -> Config {
        Config {
            regions: vec!["local".into()],
            links: vec![],
            local_latency_us: 50,
            online_shards: 8,
            workers: 4,
            artifacts_dir: PathBuf::from("artifacts"),
            data_dir: std::env::temp_dir().join("geofs-data"),
            retry: RetryPolicy::default(),
            replication_lag_secs: 30,
            seed: 42,
        }
    }

    /// The 4-region managed deployment used by examples/benches.
    pub fn default_geo() -> Config {
        let topo = GeoTopology::default_four_region();
        let regions: Vec<String> = topo.regions().to_vec();
        let mut links = Vec::new();
        for (i, a) in regions.iter().enumerate() {
            for b in regions.iter().skip(i + 1) {
                links.push(RegionLink {
                    from: a.clone(),
                    to: b.clone(),
                    one_way_us: topo.one_way_us(a, b).unwrap(),
                });
            }
        }
        Config { regions, links, ..Config::default_local() }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Config> {
        let v = Json::parse(text).map_err(|e| FsError::InvalidArg(e.to_string()))?;
        let mut cfg = Config::default_local();
        if let Some(regions) = v.get("regions").as_arr() {
            cfg.regions = regions
                .iter()
                .filter_map(|r| r.as_str().map(str::to_string))
                .collect();
            if cfg.regions.is_empty() {
                return Err(FsError::InvalidArg("config: empty regions".into()));
            }
        }
        if let Some(links) = v.get("links").as_arr() {
            cfg.links = links
                .iter()
                .map(|l| -> Result<RegionLink> {
                    Ok(RegionLink {
                        from: l
                            .get("from")
                            .as_str()
                            .ok_or_else(|| FsError::InvalidArg("link missing from".into()))?
                            .to_string(),
                        to: l
                            .get("to")
                            .as_str()
                            .ok_or_else(|| FsError::InvalidArg("link missing to".into()))?
                            .to_string(),
                        one_way_us: l
                            .get("one_way_us")
                            .as_usize()
                            .ok_or_else(|| FsError::InvalidArg("link missing one_way_us".into()))?
                            as u64,
                    })
                })
                .collect::<Result<_>>()?;
        }
        if let Some(n) = v.get("online_shards").as_usize() {
            cfg.online_shards = n.max(1);
        }
        if let Some(n) = v.get("workers").as_usize() {
            cfg.workers = n.max(1);
        }
        if let Some(s) = v.get("artifacts_dir").as_str() {
            cfg.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = v.get("data_dir").as_str() {
            cfg.data_dir = PathBuf::from(s);
        }
        if let Some(n) = v.get("local_latency_us").as_usize() {
            cfg.local_latency_us = n as u64;
        }
        if let Some(n) = v.get("replication_lag_secs").as_i64() {
            cfg.replication_lag_secs = n;
        }
        if let Some(n) = v.get("seed").as_i64() {
            cfg.seed = n as u64;
        }
        if let Some(n) = v.get("retry_max_attempts").as_usize() {
            cfg.retry.max_attempts = n as u32;
        }
        Ok(cfg)
    }

    /// Build the geo topology from this config.
    pub fn topology(&self) -> Arc<GeoTopology> {
        let regions: Vec<&str> = self.regions.iter().map(String::as_str).collect();
        let links: Vec<(&str, &str, u64)> = self
            .links
            .iter()
            .map(|l| (l.from.as_str(), l.to.as_str(), l.one_way_us))
            .collect();
        Arc::new(GeoTopology::new(&regions, &links, self.local_latency_us))
    }

    pub fn home_region(&self) -> &str {
        &self.regions[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default_local();
        assert_eq!(c.home_region(), "local");
        assert!(c.topology().has_region("local"));
        let g = Config::default_geo();
        assert_eq!(g.regions.len(), 4);
        assert_eq!(g.links.len(), 6);
        assert_eq!(g.topology().one_way_us("eastus", "westus").unwrap(), 30_000);
    }

    #[test]
    fn parse_overrides_defaults() {
        let c = Config::parse(
            r#"{
              "regions": ["a", "b"],
              "links": [{"from":"a","to":"b","one_way_us":5000}],
              "online_shards": 3,
              "workers": 2,
              "artifacts_dir": "/x/artifacts",
              "seed": 7,
              "retry_max_attempts": 9
            }"#,
        )
        .unwrap();
        assert_eq!(c.regions, vec!["a", "b"]);
        assert_eq!(c.online_shards, 3);
        assert_eq!(c.artifacts_dir, PathBuf::from("/x/artifacts"));
        assert_eq!(c.seed, 7);
        assert_eq!(c.retry.max_attempts, 9);
        assert_eq!(c.topology().rtt_us("a", "b").unwrap(), 10_000);
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(Config::parse("not json").is_err());
        assert!(Config::parse(r#"{"regions": []}"#).is_err());
        assert!(Config::parse(r#"{"links": [{"from":"a"}]}"#).is_err());
    }

    #[test]
    fn load_roundtrip() {
        let p = std::env::temp_dir().join(format!("geofs-cfg-{}.json", std::process::id()));
        std::fs::write(&p, r#"{"workers": 6}"#).unwrap();
        assert_eq!(Config::load(&p).unwrap().workers, 6);
        std::fs::remove_file(&p).unwrap();
    }
}
