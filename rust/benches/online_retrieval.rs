//! Experiment E9 (§2.1 SLA): online retrieval latency/throughput —
//! point lookups across shard counts, micro-batched lookups, and the
//! batched `get_many` path vs equivalent per-key `get` loops (single-
//! and multi-threaded, including under a live `scale_to` rebalancer).

use std::sync::Arc;

use geofs::benchkit::{fmt_ns, fmt_rate, Bencher, Table};
use geofs::online_store::OnlineStore;
use geofs::serving::batcher::{BatcherConfig, MicroBatcher};
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

fn store_with(shards: usize, entities: u64) -> Arc<OnlineStore> {
    let s = Arc::new(OnlineStore::new(shards));
    let recs: Vec<FeatureRecord> = (0..entities)
        .map(|i| FeatureRecord::new(i, 1_000, 2_000, vec![i as f32; 5]))
        .collect();
    s.merge("t", &recs, 2_000);
    s
}

fn main() {
    let bench = Bencher::new();
    let entities = 100_000u64;

    let mut t1 = Table::new(
        "E9a: online point lookup vs shard count (100k entities)",
        Table::LATENCY_HEADERS,
    );
    for shards in [1usize, 4, 16, 64] {
        let store = store_with(shards, entities);
        let mut rng = Rng::new(1);
        let m = bench.run(&format!("{shards} shard(s)"), 1.0, || {
            store.get("t", rng.below(entities), 3_000)
        });
        t1.latency_row(&m);
    }
    t1.print();

    let mut t2 = Table::new(
        "E9b: concurrent readers (16 shards, 8 threads hammering)",
        Table::LATENCY_HEADERS,
    );
    let store = store_with(16, entities);
    // Background load.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let store = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::hint::black_box(store.get("t", rng.below(entities), 3_000));
                }
            })
        })
        .collect();
    let mut rng = Rng::new(2);
    let m = bench.run("under load", 1.0, || store.get("t", rng.below(entities), 3_000));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
    t2.latency_row(&m);
    t2.print();

    let mut t3 = Table::new(
        "E9c: micro-batched lookups (batch amortization)",
        &["batch size", "mean/flush", "lookups/s"],
    );
    for batch in [1usize, 8, 64, 256] {
        let store = store_with(16, entities);
        let b = MicroBatcher::new(BatcherConfig { max_batch: batch, max_wait_us: 0 });
        let mut rng = Rng::new(3);
        let m = bench.run(&format!("batch={batch}"), batch as f64, || {
            for _ in 0..batch {
                b.push("t", rng.below(entities), 0);
            }
            b.flush(&store, 3_000, 1)
        });
        t3.row(&[format!("{batch}"), fmt_ns(m.mean_ns()), fmt_rate(m.throughput())]);
    }
    t3.print();

    // ---- E9d: batched get_many vs equivalent per-key get loop -----------
    let mut t4 = Table::new(
        "E9d: get_many vs per-key get loop (16 shards, single thread)",
        &["keys", "path", "mean/batch", "lookups/s", "speedup"],
    );
    let store = store_with(16, entities);
    for keys in [8usize, 64, 256, 1024] {
        let mut rng = Rng::new(4);
        let key_sets: Vec<Vec<u64>> = (0..32)
            .map(|_| (0..keys).map(|_| rng.below(entities)).collect())
            .collect();
        let mut k = 0usize;
        let m_batch = bench.run(&format!("{keys}/get_many"), keys as f64, || {
            k = (k + 1) % key_sets.len();
            store.get_many("t", &key_sets[k], 3_000)
        });
        let mut k = 0usize;
        let m_point = bench.run(&format!("{keys}/point"), keys as f64, || {
            k = (k + 1) % key_sets.len();
            key_sets[k]
                .iter()
                .map(|&e| store.get("t", e, 3_000))
                .collect::<Vec<_>>()
        });
        let speedup = m_point.mean_ns() / m_batch.mean_ns();
        t4.row(&[
            keys.to_string(),
            "get_many".into(),
            fmt_ns(m_batch.mean_ns()),
            fmt_rate(m_batch.throughput()),
            format!("{speedup:.2}x vs point"),
        ]);
        t4.row(&[
            keys.to_string(),
            "per-key get".into(),
            fmt_ns(m_point.mean_ns()),
            fmt_rate(m_point.throughput()),
            "1.00x".into(),
        ]);
    }
    t4.print();

    // ---- E9e: multi-threaded batched vs point, with live rebalances ------
    let mut t5 = Table::new(
        "E9e: 8 reader threads × 256-key lookups, scale_to(8↔32) rebalancing live",
        &["path", "wall time", "lookups/s (aggregate)"],
    );
    for (label, batched) in [("get_many", true), ("per-key get", false)] {
        let store = store_with(16, entities);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let rebalancer = {
            let store = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut k = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    store.scale_to(if k % 2 == 0 { 8 } else { 32 }).unwrap();
                    k += 1;
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            })
        };
        const ROUNDS: usize = 200;
        const KEYS: usize = 256;
        let t0 = std::time::Instant::now();
        let readers: Vec<_> = (0..8u64)
            .map(|t| {
                let store = store.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..ROUNDS {
                        let keys: Vec<u64> = (0..KEYS).map(|_| rng.below(entities)).collect();
                        if batched {
                            std::hint::black_box(store.get_many("t", &keys, 3_000));
                        } else {
                            for &e in &keys {
                                std::hint::black_box(store.get("t", e, 3_000));
                            }
                        }
                    }
                })
            })
            .collect();
        for h in readers {
            h.join().unwrap();
        }
        let dt = t0.elapsed();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        rebalancer.join().unwrap();
        let total = (8 * ROUNDS * KEYS) as f64;
        t5.row(&[
            label.to_string(),
            format!("{dt:.2?}"),
            fmt_rate(total / dt.as_secs_f64()),
        ]);
    }
    t5.print();

    println!(
        "\nShape check: get_many amortizes the snapshot load, TTL resolution and\n\
         per-shard locking over the batch, so it must beat the equivalent per-key\n\
         loop at every batch size ≥ 8 — single-threaded and under reader\n\
         concurrency with live rebalances (E9e), where point reads additionally\n\
         pay one snapshot validation per key."
    );
}
