//! Experiment E9 (§2.1 SLA): online retrieval latency/throughput —
//! point lookups across shard counts, micro-batched lookups, the
//! batched `get_many` path vs equivalent per-key `get` loops (single-
//! and multi-threaded, including under a live `scale_to` rebalancer),
//! and E9f: read-vs-write interference of the seqlock interior against
//! the pre-seqlock per-shard `RwLock<HashMap>` baseline.

use std::sync::Arc;

use geofs::benchkit::{fmt_ns, fmt_rate, Bencher, Table};
use geofs::online_store::OnlineStore;
use geofs::serving::batcher::{BatcherConfig, MicroBatcher};
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

fn store_with(shards: usize, entities: u64) -> Arc<OnlineStore> {
    let s = Arc::new(OnlineStore::new(shards));
    let recs: Vec<FeatureRecord> = (0..entities)
        .map(|i| FeatureRecord::new(i, 1_000, 2_000, vec![i as f32; 5]))
        .collect();
    s.merge("t", &recs, 2_000);
    s
}

fn main() {
    let bench = Bencher::new();
    let entities = 100_000u64;

    let mut t1 = Table::new(
        "E9a: online point lookup vs shard count (100k entities)",
        Table::LATENCY_HEADERS,
    );
    for shards in [1usize, 4, 16, 64] {
        let store = store_with(shards, entities);
        let mut rng = Rng::new(1);
        let m = bench.run(&format!("{shards} shard(s)"), 1.0, || {
            store.get("t", rng.below(entities), 3_000)
        });
        t1.latency_row(&m);
    }
    t1.print();

    let mut t2 = Table::new(
        "E9b: concurrent readers (16 shards, 8 threads hammering)",
        Table::LATENCY_HEADERS,
    );
    let store = store_with(16, entities);
    // Background load.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let store = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::hint::black_box(store.get("t", rng.below(entities), 3_000));
                }
            })
        })
        .collect();
    let mut rng = Rng::new(2);
    let m = bench.run("under load", 1.0, || store.get("t", rng.below(entities), 3_000));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
    t2.latency_row(&m);
    t2.print();

    let mut t3 = Table::new(
        "E9c: micro-batched lookups (batch amortization)",
        &["batch size", "mean/flush", "lookups/s"],
    );
    for batch in [1usize, 8, 64, 256] {
        let store = store_with(16, entities);
        let b = MicroBatcher::new(BatcherConfig { max_batch: batch, max_wait_us: 0 });
        let mut rng = Rng::new(3);
        let m = bench.run(&format!("batch={batch}"), batch as f64, || {
            for _ in 0..batch {
                b.push("t", rng.below(entities), 0);
            }
            b.flush(&store, 3_000, 1)
        });
        t3.row(&[format!("{batch}"), fmt_ns(m.mean_ns()), fmt_rate(m.throughput())]);
    }
    t3.print();

    // ---- E9d: batched get_many vs equivalent per-key get loop -----------
    let mut t4 = Table::new(
        "E9d: get_many vs per-key get loop (16 shards, single thread)",
        &["keys", "path", "mean/batch", "lookups/s", "speedup"],
    );
    let store = store_with(16, entities);
    for keys in [8usize, 64, 256, 1024] {
        let mut rng = Rng::new(4);
        let key_sets: Vec<Vec<u64>> = (0..32)
            .map(|_| (0..keys).map(|_| rng.below(entities)).collect())
            .collect();
        let mut k = 0usize;
        let m_batch = bench.run(&format!("{keys}/get_many"), keys as f64, || {
            k = (k + 1) % key_sets.len();
            store.get_many("t", &key_sets[k], 3_000)
        });
        let mut k = 0usize;
        let m_point = bench.run(&format!("{keys}/point"), keys as f64, || {
            k = (k + 1) % key_sets.len();
            key_sets[k]
                .iter()
                .map(|&e| store.get("t", e, 3_000))
                .collect::<Vec<_>>()
        });
        let speedup = m_point.mean_ns() / m_batch.mean_ns();
        t4.row(&[
            keys.to_string(),
            "get_many".into(),
            fmt_ns(m_batch.mean_ns()),
            fmt_rate(m_batch.throughput()),
            format!("{speedup:.2}x vs point"),
        ]);
        t4.row(&[
            keys.to_string(),
            "per-key get".into(),
            fmt_ns(m_point.mean_ns()),
            fmt_rate(m_point.throughput()),
            "1.00x".into(),
        ]);
    }
    t4.print();

    // ---- E9e: multi-threaded batched vs point, with live rebalances ------
    let mut t5 = Table::new(
        "E9e: 8 reader threads × 256-key lookups, scale_to(8↔32) rebalancing live",
        &["path", "wall time", "lookups/s (aggregate)"],
    );
    for (label, batched) in [("get_many", true), ("per-key get", false)] {
        let store = store_with(16, entities);
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let rebalancer = {
            let store = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut k = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    store.scale_to(if k % 2 == 0 { 8 } else { 32 }).unwrap();
                    k += 1;
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            })
        };
        const ROUNDS: usize = 200;
        const KEYS: usize = 256;
        let t0 = std::time::Instant::now();
        let readers: Vec<_> = (0..8u64)
            .map(|t| {
                let store = store.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..ROUNDS {
                        let keys: Vec<u64> = (0..KEYS).map(|_| rng.below(entities)).collect();
                        if batched {
                            std::hint::black_box(store.get_many("t", &keys, 3_000));
                        } else {
                            for &e in &keys {
                                std::hint::black_box(store.get("t", e, 3_000));
                            }
                        }
                    }
                })
            })
            .collect();
        for h in readers {
            h.join().unwrap();
        }
        let dt = t0.elapsed();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        rebalancer.join().unwrap();
        let total = (8 * ROUNDS * KEYS) as f64;
        t5.row(&[
            label.to_string(),
            format!("{dt:.2?}"),
            fmt_rate(total / dt.as_secs_f64()),
        ]);
    }
    t5.print();

    // ---- E9f: read-vs-write interference — seqlock vs shard-RwLock -------
    // The pre-seqlock online interior (per-shard `RwLock<HashMap>`) is
    // embedded here as the old-path baseline: identical avalanche
    // sharding and Alg-2 version compare, but readers take the shard
    // read lock — so a concurrent writer holding the write lock stalls
    // every reader of that shard.
    struct LockShards {
        shards: Vec<std::sync::RwLock<std::collections::HashMap<u64, FeatureRecord>>>,
    }
    impl LockShards {
        fn with(n: usize, entities: u64) -> Arc<Self> {
            let s = Arc::new(LockShards { shards: (0..n).map(|_| Default::default()).collect() });
            for e in 0..entities {
                s.merge(FeatureRecord::new(e, 1_000, 2_000, vec![e as f32; 5]));
            }
            s
        }
        fn idx(&self, e: u64) -> usize {
            let mut x = e.wrapping_add(0x9e3779b97f4a7c15);
            x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
            ((x ^ (x >> 31)) % self.shards.len() as u64) as usize
        }
        fn merge(&self, r: FeatureRecord) {
            let mut m = self.shards[self.idx(r.entity)].write().unwrap();
            match m.get(&r.entity) {
                Some(old) if r.version() <= old.version() => {}
                _ => {
                    m.insert(r.entity, r);
                }
            }
        }
        fn get(&self, e: u64) -> Option<FeatureRecord> {
            self.shards[self.idx(e)].read().unwrap().get(&e).cloned()
        }
    }

    let mut t6 = Table::new(
        "E9f: read latency under 0/1/4 concurrent writers — seqlock vs shard-RwLock (16 shards)",
        &["path", "writers", "op", "p50", "p99"],
    );
    let seq_store = store_with(16, entities);
    let lock_store = LockShards::with(16, entities);
    // Seqlock 256-key-batch p99 per writer count — the acceptance guard.
    let mut seq_batch_p99 = [0u64; 3];
    for (wi, &writers) in [0usize, 1, 4].iter().enumerate() {
        for &(label, is_seq) in &[("seqlock", true), ("shard-rwlock", false)] {
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let handles: Vec<_> = (0..writers)
                .map(|t| {
                    let stop = stop.clone();
                    let seq = seq_store.clone();
                    let lock = lock_store.clone();
                    std::thread::spawn(move || {
                        let mut rng = Rng::new(900 + t as u64);
                        let mut ver = 10_000i64;
                        while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                            let e = rng.below(entities);
                            ver += 1;
                            let r = FeatureRecord::new(e, ver, ver + 1, vec![e as f32; 5]);
                            if is_seq {
                                seq.merge("t", &[r], 3_000);
                            } else {
                                lock.merge(r);
                            }
                        }
                    })
                })
                .collect();
            let mut rng = Rng::new(5);
            let m_point = bench.run(&format!("E9f {label} point {writers}w"), 1.0, || {
                let e = rng.below(entities);
                if is_seq {
                    std::hint::black_box(seq_store.get("t", e, 3_000)).is_some()
                } else {
                    std::hint::black_box(lock_store.get(e)).is_some()
                }
            });
            let mut rng = Rng::new(6);
            let key_sets: Vec<Vec<u64>> =
                (0..32).map(|_| (0..256).map(|_| rng.below(entities)).collect()).collect();
            let mut k = 0usize;
            let m_batch = bench.run(&format!("E9f {label} batch {writers}w"), 256.0, || {
                k = (k + 1) % key_sets.len();
                if is_seq {
                    std::hint::black_box(seq_store.get_many("t", &key_sets[k], 3_000)).len()
                } else {
                    std::hint::black_box(
                        key_sets[k].iter().map(|&e| lock_store.get(e)).collect::<Vec<_>>(),
                    )
                    .len()
                }
            });
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            for h in handles {
                h.join().unwrap();
            }
            for (op, m) in [("point", &m_point), ("256-key batch", &m_batch)] {
                t6.row(&[
                    label.to_string(),
                    writers.to_string(),
                    op.into(),
                    fmt_ns(m.p50_ns() as f64),
                    fmt_ns(m.p99_ns() as f64),
                ]);
            }
            if is_seq {
                seq_batch_p99[wi] = m_batch.p99_ns();
            }
        }
    }
    t6.print();
    let ratio = seq_batch_p99[2] as f64 / seq_batch_p99[0].max(1) as f64;
    println!(
        "\nE9f guard: seqlock 256-key batch p99 under 4 writers = {ratio:.2}x the\n\
         0-writer p99 (acceptance: within 2x — readers never take a lock a writer\n\
         holds, so writer count must not multiply read tail latency the way the\n\
         shard-rwlock rows do)."
    );

    println!(
        "\nShape check: get_many amortizes the snapshot load and TTL resolution\n\
         over the batch, so it must beat the equivalent per-key loop at every\n\
         batch size ≥ 8 — single-threaded and under reader concurrency with live\n\
         rebalances (E9e), where point reads additionally pay one snapshot\n\
         validation per key. E9f pins the tentpole: seqlock read p50/p99 must be\n\
         flat in writer count, while the embedded shard-RwLock baseline degrades."
    );
}
