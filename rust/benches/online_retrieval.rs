//! Experiment E9 (§2.1 SLA): online retrieval latency/throughput —
//! point lookups across shard counts, and micro-batched lookups.

use std::sync::Arc;

use geofs::benchkit::{Bencher, Table};
use geofs::online_store::OnlineStore;
use geofs::serving::batcher::{BatcherConfig, MicroBatcher};
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

fn store_with(shards: usize, entities: u64) -> Arc<OnlineStore> {
    let s = Arc::new(OnlineStore::new(shards));
    let recs: Vec<FeatureRecord> = (0..entities)
        .map(|i| FeatureRecord::new(i, 1_000, 2_000, vec![i as f32; 5]))
        .collect();
    s.merge("t", &recs, 2_000);
    s
}

fn main() {
    let bench = Bencher::new();
    let entities = 100_000u64;

    let mut t1 = Table::new(
        "E9a: online point lookup vs shard count (100k entities)",
        Table::LATENCY_HEADERS,
    );
    for shards in [1usize, 4, 16, 64] {
        let store = store_with(shards, entities);
        let mut rng = Rng::new(1);
        let m = bench.run(&format!("{shards} shard(s)"), 1.0, || {
            store.get("t", rng.below(entities), 3_000)
        });
        t1.latency_row(&m);
    }
    t1.print();

    let mut t2 = Table::new(
        "E9b: concurrent readers (16 shards, 8 threads hammering)",
        Table::LATENCY_HEADERS,
    );
    let store = store_with(16, entities);
    // Background load.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let store = store.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(t);
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    std::hint::black_box(store.get("t", rng.below(entities), 3_000));
                }
            })
        })
        .collect();
    let mut rng = Rng::new(2);
    let m = bench.run("under load", 1.0, || store.get("t", rng.below(entities), 3_000));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
    t2.latency_row(&m);
    t2.print();

    let mut t3 = Table::new(
        "E9c: micro-batched lookups (batch amortization)",
        &["batch size", "mean/flush", "lookups/s"],
    );
    for batch in [1usize, 8, 64, 256] {
        let store = store_with(16, entities);
        let b = MicroBatcher::new(BatcherConfig { max_batch: batch, max_wait_us: 0 });
        let mut rng = Rng::new(3);
        let m = bench.run(&format!("batch={batch}"), batch as f64, || {
            for _ in 0..batch {
                b.push("t", rng.below(entities), 0);
            }
            b.flush(&store, 3_000, 1)
        });
        t3.row(&[
            format!("{batch}"),
            geofs::benchkit::fmt_ns(m.mean_ns()),
            geofs::benchkit::fmt_rate(m.throughput()),
        ]);
    }
    t3.print();
}
