//! Experiment E-DUR: the price of durability and the speed of recovery.
//!
//! Four claims from the crash-safe durability layer (ISSUE 9 + 10):
//!
//! * **append overhead** — a durable WAL append with per-frame fsync
//!   (the ack point) vs fsync-off vs the RAM-only partitioned log the
//!   read path is built on. The fsync number is the real cost of the
//!   "acked ⇒ survives a crash" guarantee.
//! * **group commit amortizes the ack** — an appender-concurrency ×
//!   sync-policy grid (1/4/16 threads × PerAppend / GroupCommit{0} /
//!   GroupCommit{500µs}) reports throughput, ack p50/p99, and the mean
//!   group size (appends per completed sync). Under contention the
//!   leader/follower protocol turns N per-frame fsyncs into one
//!   covering sync without weakening the ack: every cell ends with a
//!   recovery-equivalence guard proving all acked records reopen.
//! * **recovery is tail-proportional** — reopening a store replays the
//!   newest valid manifest plus the WAL tail above the checkpointed
//!   floors; time scales with the tail since the last checkpoint, not
//!   with total history (never a full segment dump).
//! * **checkpoint commit is cheap** — publishing a manifest generation
//!   is one temp-file write + atomic rename, independent of how much
//!   data the store holds.
//!
//! Writes machine-readable results to `BENCH_dur.json` (override the
//! path with `GEOFS_BENCH_DUR_OUT`); `GEOFS_BENCH_FAST=1` shrinks the
//! workload for CI smoke runs.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use geofs::benchkit::{fmt_ns, fmt_rate, Bencher, Measurement, Table};
use geofs::monitor::metrics::MetricsRegistry;
use geofs::monitor::names;
use geofs::storage::{DurableLogOptions, DurableStore, RealFs, SyncPolicy};
use geofs::stream::{PartitionedLog, StreamEvent};
use geofs::testkit::TempDir;
use geofs::util::json::Json;

fn ev(seq: u64) -> StreamEvent {
    StreamEvent::new(seq, format!("cust_{:04}", seq % 512), seq as i64, seq as f32)
}

fn open_store(dir: &Path) -> Arc<DurableStore> {
    DurableStore::open(Arc::new(RealFs), dir, 0).unwrap()
}

fn wal_opts(sync: SyncPolicy) -> DurableLogOptions {
    DurableLogOptions { fragment_max_bytes: 64 << 10, sync, ..Default::default() }
}

/// Append `total` records, then (if `tail < total`) advance the
/// consumer floor so only the last `tail` records remain above the
/// checkpoint — the slice recovery must actually replay. Two extra
/// checkpoint generations age the pre-truncation manifest out of the
/// GC live set so the reclaimed fragments are really gone.
fn build_tail(dir: &Path, total: u64, tail: u64) {
    let store = open_store(dir);
    let log = store.open_log::<StreamEvent>("bench", 1, wal_opts(SyncPolicy::OsManaged)).unwrap();
    for i in 0..total {
        log.append(0, ev(i)).unwrap();
    }
    if tail < total {
        log.truncate_below(0, total - tail);
        store.commit_checkpoint(0, |_| {}).unwrap();
        store.commit_checkpoint(1, |_| {}).unwrap();
        store.gc().unwrap();
        store.gc().unwrap();
    }
}

/// One full recovery: root the newest manifest, replay the WAL tail.
fn recover(dir: &Path) -> u64 {
    let store = open_store(dir);
    let log = store.open_log::<StreamEvent>("bench", 1, wal_opts(SyncPolicy::OsManaged)).unwrap();
    log.mem().high_water(0)
}

/// One cell of the appender-concurrency × sync-policy grid: `threads`
/// appenders over one fresh single-partition durable log, each timing
/// its own acks. Group size comes from the WAL's own `wal_sync_total`
/// counter (appends ÷ completed syncs). Ends with the
/// recovery-equivalence guard: a clean reopen must surface every acked
/// record, whichever policy produced it.
struct GridCell {
    threads: usize,
    policy: &'static str,
    total: u64,
    syncs: u64,
    wall_s: f64,
    ack_p50_ns: u64,
    ack_p99_ns: u64,
}

impl GridCell {
    fn throughput(&self) -> f64 {
        self.total as f64 / self.wall_s.max(1e-9)
    }

    fn mean_group(&self) -> f64 {
        self.total as f64 / self.syncs.max(1) as f64
    }
}

fn run_grid_cell(
    threads: usize,
    policy: SyncPolicy,
    policy_label: &'static str,
    per_thread: u64,
) -> GridCell {
    let dir = TempDir::new("bench-dur-grid");
    let metrics = Arc::new(MetricsRegistry::new());
    let store = open_store(dir.path());
    let mut opts = wal_opts(policy);
    opts.metrics = Some(metrics.clone());
    let log = store.open_log::<StreamEvent>("bench", 1, opts).unwrap();

    let start = Instant::now();
    let mut lats: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let log = &log;
                s.spawn(move || {
                    let mut lats = Vec::with_capacity(per_thread as usize);
                    for i in 0..per_thread {
                        let seq = t as u64 * 1_000_000 + i;
                        let t0 = Instant::now();
                        log.append(0, ev(seq)).unwrap();
                        lats.push(t0.elapsed().as_nanos() as u64);
                    }
                    lats
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall_s = start.elapsed().as_secs_f64();
    lats.sort_unstable();
    let total = lats.len() as u64;
    let q = |f: f64| lats[((lats.len() - 1) as f64 * f) as usize];
    let cell = GridCell {
        threads,
        policy: policy_label,
        total,
        syncs: metrics.counter(names::WAL_SYNC_TOTAL),
        wall_s,
        ack_p50_ns: q(0.50),
        ack_p99_ns: q(0.99),
    };

    drop(log);
    drop(store);
    assert_eq!(
        recover(dir.path()),
        total,
        "recovery-equivalence: every acked append must survive a clean reopen \
         ({threads} threads, {policy_label})"
    );
    cell
}

fn m_json(m: &Measurement) -> Json {
    Json::obj(vec![
        ("name", Json::str(m.name.as_str())),
        ("iters", Json::num(m.iters as f64)),
        ("mean_ns", Json::num(m.mean_ns())),
        ("p50_ns", Json::num(m.p50_ns() as f64)),
        ("p99_ns", Json::num(m.p99_ns() as f64)),
        ("throughput_per_s", Json::num(m.throughput())),
    ])
}

fn main() {
    let fast = std::env::var("GEOFS_BENCH_FAST").is_ok();
    let (total, tail) = if fast { (2_000u64, 256u64) } else { (16_000u64, 1_024u64) };
    let b = Bencher::new();

    // --- append: RAM baseline vs WAL without fsync vs WAL with fsync.
    let ram = PartitionedLog::<StreamEvent>::new(1);
    let mut seq_ram = 0u64;
    let m_ram = b.run("append ram baseline", 1.0, || {
        seq_ram += 1;
        ram.append(0, ev(seq_ram))
    });

    let dir_nosync = TempDir::new("bench-dur-nosync");
    let store_nosync = open_store(dir_nosync.path());
    let log_nosync =
        store_nosync.open_log::<StreamEvent>("bench", 1, wal_opts(SyncPolicy::OsManaged)).unwrap();
    let mut seq_ns = 0u64;
    let m_nosync = b.run("append wal fsync=off", 1.0, || {
        seq_ns += 1;
        log_nosync.append(0, ev(seq_ns)).unwrap()
    });

    let dir_sync = TempDir::new("bench-dur-sync");
    let store_sync = open_store(dir_sync.path());
    let log_sync =
        store_sync.open_log::<StreamEvent>("bench", 1, wal_opts(SyncPolicy::PerAppend)).unwrap();
    let mut seq_s = 0u64;
    let m_sync = b.run("append wal fsync=on (ack)", 1.0, || {
        seq_s += 1;
        log_sync.append(0, ev(seq_s)).unwrap()
    });

    // --- appender-concurrency × sync-policy grid: how far group
    // commit amortizes the per-ack fsync as contention grows. Each
    // cell is a fresh store; GroupCommit{0} coalesces only what piles
    // up naturally behind the leader, GroupCommit{500µs} lets the
    // leader wait out stragglers for bigger groups at higher ack p50.
    let per_thread = if fast { 64u64 } else { 512u64 };
    let policies: [(SyncPolicy, &str); 3] = [
        (SyncPolicy::PerAppend, "per_append"),
        (SyncPolicy::GroupCommit { max_delay_us: 0, max_batch: 0 }, "group_commit(delay=0)"),
        (
            SyncPolicy::GroupCommit { max_delay_us: 500, max_batch: 64 },
            "group_commit(delay=500us)",
        ),
    ];
    let mut grid: Vec<GridCell> = Vec::new();
    for threads in [1usize, 4, 16] {
        for (policy, label) in policies {
            grid.push(run_grid_cell(threads, policy, label, per_thread));
        }
    }

    // --- recovery: full tail vs checkpoint-truncated tail over the
    // same total history. The first reopen seals the crashed active
    // fragment (one manifest commit); warmup absorbs it and every
    // later iteration is the pure read path.
    let dir_full = TempDir::new("bench-dur-rec-full");
    build_tail(dir_full.path(), total, total);
    assert_eq!(recover(dir_full.path()), total);
    let m_rec_full = b.run(
        &format!("recover tail={total}"),
        total as f64,
        || recover(dir_full.path()),
    );

    let dir_tail = TempDir::new("bench-dur-rec-tail");
    build_tail(dir_tail.path(), total, tail);
    assert_eq!(recover(dir_tail.path()), total);
    let m_rec_tail = b.run(
        &format!("recover tail={tail} (post-ckpt)"),
        tail as f64,
        || recover(dir_tail.path()),
    );

    // --- checkpoint commit on the store that just absorbed the
    // fsync=off append workload (realistically sized manifest).
    let mut ckpt_now = 10i64;
    let m_ckpt = b.run("checkpoint commit", 1.0, || {
        ckpt_now += 1;
        store_nosync.commit_checkpoint(ckpt_now, |_| {}).unwrap()
    });

    let mut t = Table::new(
        "E-DUR — durable WAL append, recovery, checkpoint commit",
        Table::LATENCY_HEADERS,
    );
    t.latency_row(&m_ram);
    t.latency_row(&m_nosync);
    t.latency_row(&m_sync);
    t.latency_row(&m_rec_full);
    t.latency_row(&m_rec_tail);
    t.latency_row(&m_ckpt);
    t.print();

    let mut g = Table::new(
        "E-DUR grid — appender threads × sync policy (per-thread appends × acks)",
        &["threads", "policy", "throughput", "ack p50", "ack p99", "mean group", "syncs"],
    );
    for c in &grid {
        g.row(&[
            c.threads.to_string(),
            c.policy.to_string(),
            fmt_rate(c.throughput()),
            fmt_ns(c.ack_p50_ns as f64),
            fmt_ns(c.ack_p99_ns as f64),
            format!("{:.1}", c.mean_group()),
            c.syncs.to_string(),
        ]);
    }
    g.print();

    let fsync_penalty = m_sync.mean_ns() / m_ram.mean_ns().max(1.0);
    let tail_speedup = m_rec_full.mean_ns() / m_rec_tail.mean_ns().max(1.0);
    println!(
        "\nack cost: fsync append {} vs ram {} (×{:.0}); fsync=off {} keeps the format, drops the guarantee",
        fmt_ns(m_sync.mean_ns()),
        fmt_ns(m_ram.mean_ns()),
        fsync_penalty,
        fmt_ns(m_nosync.mean_ns()),
    );
    println!(
        "recovery: full history ({total} recs) {}, post-checkpoint tail ({tail} recs) {} — ×{:.1} faster, replay rate {}",
        fmt_ns(m_rec_full.mean_ns()),
        fmt_ns(m_rec_tail.mean_ns()),
        tail_speedup,
        fmt_rate(m_rec_full.throughput()),
    );
    println!("checkpoint commit: {} per generation", fmt_ns(m_ckpt.mean_ns()));

    // Headline amortization: group commit vs per-append fsync at the
    // highest contention level in the grid.
    let cell = |threads: usize, policy: &str| {
        grid.iter().find(|c| c.threads == threads && c.policy == policy).unwrap()
    };
    let gc16 = cell(16, "group_commit(delay=0)");
    let pa16 = cell(16, "per_append");
    let coalesce_x = gc16.throughput() / pa16.throughput().max(1e-9);
    println!(
        "group commit @16 threads: {} vs per-append {} (×{:.1}), mean group {:.1} frames/sync",
        fmt_rate(gc16.throughput()),
        fmt_rate(pa16.throughput()),
        coalesce_x,
        gc16.mean_group(),
    );

    let g_json = |c: &GridCell| {
        Json::obj(vec![
            ("threads", Json::num(c.threads as f64)),
            ("policy", Json::str(c.policy)),
            ("appends", Json::num(c.total as f64)),
            ("throughput_per_s", Json::num(c.throughput())),
            ("ack_p50_ns", Json::num(c.ack_p50_ns as f64)),
            ("ack_p99_ns", Json::num(c.ack_p99_ns as f64)),
            ("syncs", Json::num(c.syncs as f64)),
            ("mean_group_size", Json::num(c.mean_group())),
        ])
    };

    let doc = Json::obj(vec![
        ("experiment", Json::str("E-DUR")),
        ("fast", Json::num(u8::from(fast))),
        ("total_records", Json::num(total as f64)),
        ("tail_records", Json::num(tail as f64)),
        ("fsync_penalty_x", Json::num(fsync_penalty)),
        ("tail_recovery_speedup_x", Json::num(tail_speedup)),
        ("group_commit_coalesce_x", Json::num(coalesce_x)),
        ("grid", Json::Arr(grid.iter().map(g_json).collect())),
        (
            "measurements",
            Json::Arr(vec![
                m_json(&m_ram),
                m_json(&m_nosync),
                m_json(&m_sync),
                m_json(&m_rec_full),
                m_json(&m_rec_tail),
                m_json(&m_ckpt),
            ]),
        ),
    ]);
    let out = std::env::var("GEOFS_BENCH_DUR_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("BENCH_dur.json"));
    std::fs::write(&out, doc.to_string()).expect("write BENCH_dur.json");
    println!("wrote {}", out.display());
}
