//! Experiment E4 (§4.4 + §2.1): point-in-time join throughput.
//!
//! Before/after for the PR 2 offline-path rebuild, three engines over
//! the same store and spine:
//!
//! * **merge-join** — the current engine: streaming merge-join of the
//!   entity-sorted spine against the store's sorted columnar segments
//!   (no per-query index build, no record clones); also measured with
//!   the thread-pool fan-out.
//! * **per-query index** — the previous engine's strategy, reconstructed
//!   as a baseline: scan the window into owned `FeatureRecord`s, build a
//!   `PitIndex` (hash + per-entity sort) per query, then binary-search
//!   lookups.
//! * **naive-scan** — per-observation full scan (`naive_training_frame`),
//!   the differential-test oracle; O(obs × rows), timed on a subset.

use std::collections::HashMap;
use std::sync::Arc;

use geofs::benchkit::{fmt_ns, fmt_rate, Bencher, Table};
use geofs::exec::ThreadPool;
use geofs::metadata::assets::{FeatureSetSpec, SourceSpec};
use geofs::offline_store::OfflineStore;
use geofs::query::offline::{naive_training_frame, OfflineQueryEngine};
use geofs::query::pit::{Observation, PitConfig, PitIndex};
use geofs::query::spec::FeatureRef;
use geofs::types::time::{Granularity, DAY};
use geofs::types::{FeatureRecord, FeatureWindow};
use geofs::util::rng::Rng;

fn setup(entities: u64, days: i64) -> (Arc<OfflineStore>, HashMap<String, FeatureSetSpec>) {
    let store = Arc::new(OfflineStore::new());
    let mut rows = Vec::new();
    for d in 1..=days {
        for e in 0..entities {
            rows.push(FeatureRecord::new(
                e,
                d * DAY,
                d * DAY + 600,
                vec![d as f32, e as f32, 1.0, 0.0, 2.0],
            ));
        }
    }
    store.merge("txn:1", &rows);
    let mut specs = HashMap::new();
    specs.insert(
        "txn".to_string(),
        FeatureSetSpec::rolling("txn", 1, "customer", SourceSpec::synthetic(0), Granularity::daily(), 30),
    );
    (store, specs)
}

fn observations(rng: &mut Rng, n: usize, entities: u64, days: i64) -> Vec<Observation> {
    (0..n)
        .map(|_| Observation { entity: rng.below(entities + 2), ts: rng.range(DAY, days * DAY) })
        .collect()
}

/// The PR 1 engine, reconstructed as the "before" baseline: full-window
/// scan into owned records, per-query `PitIndex::build` (clone + hash +
/// per-entity sort), then per-observation lookups.
fn per_query_index_cells(
    store: &OfflineStore,
    obs: &[Observation],
    cols: &[usize],
    cfg: PitConfig,
) -> Vec<Option<f32>> {
    let Some((lo, hi)) = store.event_range("txn:1") else {
        return vec![None; obs.len() * cols.len()];
    };
    let window = FeatureWindow::new(lo, hi + 1);
    let wanted: std::collections::HashSet<u64> = obs.iter().map(|o| o.entity).collect();
    let index = PitIndex::build(
        store.scan("txn:1", window).into_iter().filter(|r| wanted.contains(&r.entity)),
    );
    let mut out = vec![None; obs.len() * cols.len()];
    for (i, &o) in obs.iter().enumerate() {
        if let Some(rec) = index.lookup(o, cfg) {
            for (j, &c) in cols.iter().enumerate() {
                out[i * cols.len() + j] = rec.values.get(c).copied();
            }
        }
    }
    out
}

fn main() {
    let bench = Bencher::new();
    let pool = Arc::new(ThreadPool::new(4));
    let features = vec![
        FeatureRef::parse("txn:1:720h_sum").unwrap(),
        FeatureRef::parse("txn:1:720h_cnt").unwrap(),
    ];
    let cfg = PitConfig::default();

    let mut table = Table::new(
        "E4: PIT training-frame throughput — streaming merge-join vs per-query index vs naive scan",
        &["store rows", "observations", "engine", "mean", "obs rows/s", "speedup/row vs naive"],
    );
    for (entities, days, n_obs) in
        [(200u64, 30i64, 1_000usize), (1_000, 60, 2_000), (2_000, 90, 4_000)]
    {
        let (store, specs) = setup(entities, days);
        let engine = OfflineQueryEngine::new(store.clone());
        let pooled = OfflineQueryEngine::with_pool(store.clone(), pool.clone());
        let mut rng = Rng::new(9);
        let obs = observations(&mut rng, n_obs, entities, days);
        let rows = store.row_count("txn:1");

        // Cross-engine agreement guard before timing anything.
        let frame = engine.get_training_frame(&obs, &features, &specs, cfg).unwrap();
        assert_eq!(frame, pooled.get_training_frame(&obs, &features, &specs, cfg).unwrap());
        let baseline = per_query_index_cells(&store, &obs, &[0, 1], cfg);
        for (i, _) in obs.iter().enumerate() {
            assert_eq!(frame.value(i, 0), baseline[i * 2], "row {i} disagrees with PR1 baseline");
        }

        let m_merge = bench.run("merge-join", n_obs as f64, || {
            engine.get_training_frame(&obs, &features, &specs, cfg).unwrap()
        });
        let m_pool = bench.run("merge-join+pool", n_obs as f64, || {
            pooled.get_training_frame(&obs, &features, &specs, cfg).unwrap()
        });
        let m_index = bench.run("per-query index", n_obs as f64, || {
            per_query_index_cells(&store, &obs, &[0, 1], cfg)
        });
        // Naive join is O(obs × rows); keep its case small enough to finish.
        let naive_obs = &obs[..(n_obs / 20).max(10)];
        let m_naive = bench.run("naive", naive_obs.len() as f64, || {
            naive_training_frame(&store, naive_obs, &features, &specs, cfg).unwrap()
        });

        let naive_per_row = m_naive.mean_ns() / naive_obs.len() as f64;
        for m in [&m_merge, &m_pool, &m_index] {
            let per_row = m.mean_ns() / n_obs as f64;
            table.row(&[
                rows.to_string(),
                n_obs.to_string(),
                m.name.clone(),
                fmt_ns(m.mean_ns()),
                fmt_rate(m.throughput()),
                format!("{:.0}x", naive_per_row / per_row),
            ]);
        }
        table.row(&[
            rows.to_string(),
            naive_obs.len().to_string(),
            m_naive.name.clone(),
            fmt_ns(m_naive.mean_ns()),
            fmt_rate(m_naive.throughput()),
            "1x".into(),
        ]);
    }
    table.print();
    println!(
        "\nShape check: the merge-join scales near-linearly in observations and\n\
         never re-indexes per query (the per-query-index row pays a scan + clone +\n\
         sort on every call); the naive join degrades with store size — the reason\n\
         §3.1.6/§4.4 put a dedicated query subsystem in front of the offline store.\n\
         See EXPERIMENTS.md §E4 for how to record results."
    );
}
