//! Experiment E4 (§4.4 + §2.1): point-in-time join throughput — the
//! indexed PIT engine vs a naive per-observation full scan.

use std::collections::HashMap;
use std::sync::Arc;

use geofs::benchkit::{fmt_rate, Bencher, Table};
use geofs::metadata::assets::{FeatureSetSpec, SourceSpec};
use geofs::offline_store::OfflineStore;
use geofs::query::offline::{naive_training_frame, OfflineQueryEngine};
use geofs::query::pit::{Observation, PitConfig};
use geofs::query::spec::FeatureRef;
use geofs::types::time::{Granularity, DAY};
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

fn setup(entities: u64, days: i64) -> (Arc<OfflineStore>, HashMap<String, FeatureSetSpec>) {
    let store = Arc::new(OfflineStore::new());
    let mut rows = Vec::new();
    for d in 1..=days {
        for e in 0..entities {
            rows.push(FeatureRecord::new(
                e,
                d * DAY,
                d * DAY + 600,
                vec![d as f32, e as f32, 1.0, 0.0, 2.0],
            ));
        }
    }
    store.merge("txn:1", &rows);
    let mut specs = HashMap::new();
    specs.insert(
        "txn".to_string(),
        FeatureSetSpec::rolling("txn", 1, "customer", SourceSpec::synthetic(0), Granularity::daily(), 30),
    );
    (store, specs)
}

fn observations(rng: &mut Rng, n: usize, entities: u64, days: i64) -> Vec<Observation> {
    (0..n)
        .map(|_| Observation { entity: rng.below(entities + 2), ts: rng.range(DAY, days * DAY) })
        .collect()
}

fn main() {
    let bench = Bencher::new();
    let features = vec![
        FeatureRef::parse("txn:1:720h_sum").unwrap(),
        FeatureRef::parse("txn:1:720h_cnt").unwrap(),
    ];

    let mut table = Table::new(
        "E4: PIT training-frame throughput — indexed engine vs naive full-scan",
        &["store rows", "observations", "engine", "mean", "obs rows/s", "speedup"],
    );
    for (entities, days, n_obs) in [(200u64, 30i64, 1_000usize), (1_000, 60, 2_000), (2_000, 90, 4_000)] {
        let (store, specs) = setup(entities, days);
        let engine = OfflineQueryEngine::new(store.clone());
        let mut rng = Rng::new(9);
        let obs = observations(&mut rng, n_obs, entities, days);
        let rows = store.row_count("txn:1");

        let m_fast = bench.run("indexed", n_obs as f64, || {
            engine
                .get_training_frame(&obs, &features, &specs, PitConfig::default())
                .unwrap()
        });
        // Naive join is O(obs × rows); keep its case small enough to finish.
        let naive_obs = &obs[..(n_obs / 20).max(10)];
        let m_naive = bench.run("naive", naive_obs.len() as f64, || {
            naive_training_frame(&store, naive_obs, &features, &specs, PitConfig::default())
                .unwrap()
        });

        let speedup = m_naive.mean_ns() / naive_obs.len() as f64
            / (m_fast.mean_ns() / n_obs as f64);
        table.row(&[
            rows.to_string(),
            n_obs.to_string(),
            "indexed".into(),
            geofs::benchkit::fmt_ns(m_fast.mean_ns()),
            fmt_rate(m_fast.throughput()),
            String::new(),
        ]);
        table.row(&[
            rows.to_string(),
            naive_obs.len().to_string(),
            "naive-scan".into(),
            geofs::benchkit::fmt_ns(m_naive.mean_ns()),
            fmt_rate(m_naive.throughput()),
            format!("{speedup:.0}x slower/row"),
        ]);
    }
    table.print();
    println!(
        "\nShape check: the indexed engine scales near-linearly in observations;\n\
         the naive join degrades with store size — the reason §3.1.6/§4.4 put a\n\
         dedicated query subsystem (not ad-hoc joins) in front of the offline store."
    );
}
