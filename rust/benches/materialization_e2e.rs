//! Experiment E10 (Alg 1, §4.2–§4.3): end-to-end materialization
//! throughput through the full stack — source read → binning → AOT
//! compute → dual-store merge — incremental vs one-shot backfill.

use geofs::benchkit::{fmt_rate, Bencher, Table};
use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::DAY;
use geofs::types::FeatureWindow;

fn open(customers: usize) -> (std::sync::Arc<FeatureStore>, ChurnWorkload) {
    let fs = FeatureStore::open(Config::default_local(), OpenOptions::default())
        .expect("run `make artifacts` first");
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers, days: 14, seed: 11, ..Default::default() },
    )
    .unwrap();
    (fs, w)
}

fn main() {
    let bench = Bencher::new();

    let mut table = Table::new(
        "E10: end-to-end materialization (source→bin→AOT compute→dual merge)",
        &["customers", "mode", "mean/run", "records", "records/s"],
    );
    for customers in [32usize, 128, 512] {
        // Incremental: 14 daily ticks.
        let mut recs = 0u64;
        let mut runs = 0u64;
        let m_inc = bench.run("incremental", 1.0, || {
            let (fs, w) = open(customers);
            let mut n = 0u64;
            for day in 1..=14 {
                fs.clock.set(day * DAY);
                n += fs
                    .materialize_tick(&w.txn_table)
                    .unwrap()
                    .iter()
                    .map(|o| o.records)
                    .sum::<u64>();
            }
            recs += n;
            runs += 1;
        });
        let per_run = recs / runs.max(1);
        table.row(&[
            customers.to_string(),
            "incremental (14 ticks)".into(),
            geofs::benchkit::fmt_ns(m_inc.mean_ns()),
            per_run.to_string(),
            fmt_rate(per_run as f64 * 1e9 / m_inc.mean_ns()),
        ]);

        // Backfill: one request over the same span.
        let mut recs = 0u64;
        let mut runs = 0u64;
        let m_bf = bench.run("backfill", 1.0, || {
            let (fs, w) = open(customers);
            fs.clock.set(14 * DAY);
            let n = fs
                .backfill(&w.txn_table, FeatureWindow::new(0, 14 * DAY))
                .unwrap()
                .iter()
                .map(|o| o.records)
                .sum::<u64>();
            recs += n;
            runs += 1;
        });
        let per_run = recs / runs.max(1);
        table.row(&[
            customers.to_string(),
            "one-shot backfill".into(),
            geofs::benchkit::fmt_ns(m_bf.mean_ns()),
            per_run.to_string(),
            fmt_rate(per_run as f64 * 1e9 / m_bf.mean_ns()),
        ]);
    }
    table.print();

    println!(
        "\nShape check: backfill ≥ incremental throughput (fewer, larger jobs —\n\
         §3.1.1's coalescing rationale); both scale with entity count until the\n\
         artifact batch shape saturates."
    );
}
