//! Experiments E7 + E12: scheduler planning/claim throughput under the
//! context-aware partitioning knob, and lineage queries at scale.

use std::sync::Arc;

use geofs::benchkit::{fmt_rate, Bencher, Table};
use geofs::exec::{RetryPolicy, ThreadPool};
use geofs::lineage::{Lineage, ModelId};
use geofs::query::spec::FeatureRef;
use geofs::scheduler::{SchedulePolicy, Scheduler, WindowTracker};
use geofs::types::time::{Granularity, DAY, HOUR};
use geofs::types::FeatureWindow;
use geofs::util::Clock;

fn main() {
    let bench = Bencher::new();

    // ---- E7a: window-tracker claim/complete throughput -------------------
    let mut t1 = Table::new(
        "E7a: tracker claim+complete throughput vs coverage fragmentation",
        &["pre-existing windows", "mean/op", "ops/s"],
    );
    for frag in [0usize, 100, 1_000, 10_000] {
        let mut tracker = WindowTracker::new();
        // Fragmented coverage: disjoint 1h windows spaced 2h apart.
        for i in 0..frag {
            let s = (i as i64) * 2 * HOUR;
            let id = tracker.try_claim(FeatureWindow::new(s, s + HOUR)).unwrap();
            tracker.complete(id).unwrap();
        }
        let mut next = (frag as i64) * 2 * HOUR + DAY;
        let m = bench.run(&format!("frag={frag}"), 1.0, || {
            let w = FeatureWindow::new(next, next + HOUR);
            next += 2 * HOUR;
            let id = tracker.try_claim(w).unwrap();
            tracker.complete(id).unwrap();
        });
        t1.row(&[frag.to_string(), geofs::benchkit::fmt_ns(m.mean_ns()), fmt_rate(m.throughput())]);
    }
    t1.print();

    // ---- E7b: end-to-end tick with varying job partitioning --------------
    let mut t2 = Table::new(
        "E7b: scheduled tick (30 days due) vs max_bins_per_job (context-aware partitioning)",
        &["max bins/job", "jobs", "mean/tick", "event-days/s"],
    );
    for max_bins in [6i64, 24, 24 * 7, 24 * 30] {
        let policy = SchedulePolicy {
            granularity: Granularity(HOUR),
            interval_secs: DAY,
            source_delay_secs: 0,
            max_bins_per_job: max_bins,
        };
        let mut jobs = 0usize;
        let mut iter = 0u64;
        let m = bench.run(&format!("bins={max_bins}"), 30.0, || {
            let sched = Scheduler::new(
                Arc::new(ThreadPool::new(8)),
                Clock::fixed(30 * DAY),
                RetryPolicy::none(),
            );
            let out = sched.tick("t", &policy, 0, Arc::new(|_, _| Ok(1)));
            jobs += out.len();
            iter += 1;
        });
        t2.row(&[
            max_bins.to_string(),
            (jobs as u64 / iter.max(1)).to_string(),
            geofs::benchkit::fmt_ns(m.mean_ns()),
            fmt_rate(m.throughput()),
        ]);
    }
    t2.print();

    // ---- E12: lineage at scale -------------------------------------------
    let mut t3 = Table::new(
        "E12: lineage queries (1k models × 500 features each, §4.6 scale)",
        &["query", "mean", "ops/s"],
    );
    let lineage = Lineage::new();
    let features: Vec<FeatureRef> = (0..5_000)
        .map(|i| FeatureRef::parse(&format!("fs{}:1:f{i}", i % 50)).unwrap())
        .collect();
    for m in 0..1_000 {
        let slice: Vec<FeatureRef> =
            (0..500).map(|k| features[(m * 7 + k * 11) % features.len()].clone()).collect();
        lineage.record(ModelId { name: format!("m{m}"), version: 1 }, &slice, "eastus", 0);
    }
    let mq = bench.run("features_of(model)", 1.0, || {
        lineage.features_of(&ModelId { name: "m500".into(), version: 1 })
    });
    t3.row(&[mq.name.clone(), geofs::benchkit::fmt_ns(mq.mean_ns()), fmt_rate(mq.throughput())]);
    let mq = bench.run("models_using(feature)", 1.0, || lineage.models_using(&features[0]));
    t3.row(&[mq.name.clone(), geofs::benchkit::fmt_ns(mq.mean_ns()), fmt_rate(mq.throughput())]);
    let mq = bench.run("global_view()", 1.0, || lineage.global_view());
    t3.row(&[mq.name.clone(), geofs::benchkit::fmt_ns(mq.mean_ns()), fmt_rate(mq.throughput())]);
    t3.print();

    println!("\nShape check: claims stay O(active jobs), coalescing trades job count\nagainst window size, and lineage lookups stay O(degree) at paper scale.");
}
