//! Experiment E6 (§4.1.2, Fig 4): cross-region access vs geo-replication
//! — the latency ↔ staleness/compliance trade, per consumer region.

use std::sync::Arc;

use geofs::benchkit::{Bencher, Table};
use geofs::geo::access::CrossRegionAccess;
use geofs::geo::replication::GeoReplicator;
use geofs::geo::topology::GeoTopology;
use geofs::online_store::OnlineStore;
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

fn main() {
    let bench = Bencher::new();
    let topology = Arc::new(GeoTopology::default_four_region());
    let entities = 20_000u64;

    let home = Arc::new(OnlineStore::new(16));
    let recs: Vec<FeatureRecord> =
        (0..entities).map(|i| FeatureRecord::new(i, 1_000, 2_000, vec![i as f32])).collect();
    home.merge("t", &recs, 2_000);

    // Replicas in every non-home region, 30 s lag, fully caught up.
    let lag = 30;
    let replicator = Arc::new(GeoReplicator::new(
        ["westus", "westeurope", "southeastasia"]
            .iter()
            .map(|r| (r.to_string(), Arc::new(OnlineStore::new(16)), lag))
            .collect(),
    ));
    replicator.enqueue("t", &recs, 2_000);
    replicator.pump(2_000 + lag);

    let cross_only = CrossRegionAccess {
        topology: topology.clone(),
        home_region: "eastus".into(),
        home_store: home.clone(),
        replicator: None,
        geo_fenced: true, // compliance: data stays home
    };
    let with_replicas = CrossRegionAccess {
        topology: topology.clone(),
        home_region: "eastus".into(),
        home_store: home,
        replicator: Some(replicator.clone()),
        geo_fenced: false,
    };

    let mut table = Table::new(
        "E6: per-consumer-region lookup — cross-region access vs geo-replication",
        &["consumer", "mechanism", "sim latency p50", "staleness bound", "allowed if geo-fenced"],
    );
    for region in ["eastus", "westus", "westeurope", "southeastasia"] {
        for (label, access) in [("cross-region", &cross_only), ("replica", &with_replicas)] {
            let mut rng = Rng::new(4);
            let mut latencies: Vec<u64> = Vec::new();
            let m = bench.run(&format!("{region}/{label}"), 1.0, || {
                let out = access.lookup(region, "t", rng.below(entities), 5_000).unwrap();
                latencies.push(out.latency_us);
                out
            });
            let _ = m;
            latencies.sort();
            let p50 = latencies[latencies.len() / 2];
            let mech = access.route(region);
            table.row(&[
                region.to_string(),
                format!("{mech:?}"),
                format!("{:.1}ms", p50 as f64 / 1_000.0),
                if mech == geofs::geo::access::AccessMechanism::Replica {
                    format!("≤{lag}s")
                } else {
                    "0s".into()
                },
                if label == "cross-region" { "yes".into() } else { "no (data leaves region)".into() },
            ]);
        }
    }
    table.print();

    println!(
        "\nShape check (paper §4.1.2): replication wins tail latency everywhere\n\
         outside the home region (local ~0.5ms vs 60–220ms WAN RTT) but is\n\
         staleness-bounded and barred for geo-fenced stores; cross-region access\n\
         keeps staleness 0 and compliance, at WAN cost — matching why AzureML\n\
         shipped access control first and kept replication on the roadmap."
    );
}
