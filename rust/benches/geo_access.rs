//! Experiments E6 + E-GEO (§4.1.2, Fig 4): the replication fabric's
//! latency ↔ staleness trade, per consumer region and per consistency
//! policy, plus fabric apply throughput vs region count.
//!
//! * **E6** — per-consumer-region point lookup: cross-region access vs
//!   a fabric replica (the paper's Fig 4 comparison).
//! * **E-GEO a** — policy-routed *batched* reads across the default
//!   four-region topology: `Strong`, `BoundedStaleness` within/past the
//!   bound, `ReadYourWrites` covered/uncovered — one routing decision
//!   and one WAN RTT (or none) for a 256-key batch.
//! * **E-GEO b** — replication apply throughput vs replica-region
//!   count: one shared log, per-region cursors, per-region locks.

use std::sync::Arc;

use geofs::benchkit::{fmt_rate, Bencher, Table};
use geofs::geo::access::{AccessMechanism, CrossRegionAccess, ReadConsistency};
use geofs::geo::replication::ReplicationFabric;
use geofs::geo::topology::GeoTopology;
use geofs::online_store::OnlineStore;
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

fn main() {
    let bench = Bencher::new();
    let topology = Arc::new(GeoTopology::default_four_region());
    let entities = 20_000u64;

    let home = Arc::new(OnlineStore::new(16));
    let recs: Vec<FeatureRecord> =
        (0..entities).map(|i| FeatureRecord::new(i, 1_000, 2_000, vec![i as f32])).collect();
    home.merge("t", &recs, 2_000);

    // Fabric replicas in every non-home region, 30 s lag, fully caught up.
    let lag = 30;
    let fabric = ReplicationFabric::new(
        4,
        ["westus", "westeurope", "southeastasia"]
            .iter()
            .map(|r| (r.to_string(), Arc::new(OnlineStore::new(16)), lag))
            .collect(),
        None,
    );
    fabric.append("t", &recs, 2_000).unwrap();
    fabric.pump(2_000 + lag);

    let cross_only = CrossRegionAccess {
        topology: topology.clone(),
        home_region: "eastus".into(),
        home_store: home.clone(),
        fabric: None,
        geo_fenced: true, // compliance: data stays home
    };
    let with_replicas = CrossRegionAccess {
        topology: topology.clone(),
        home_region: "eastus".into(),
        home_store: home.clone(),
        fabric: Some(fabric.clone()),
        geo_fenced: false,
    };

    // ---- E6: per-region point lookups, mechanism comparison ------------
    let eventual = ReadConsistency::default();
    let mut table = Table::new(
        "E6: per-consumer-region lookup — cross-region access vs fabric replica",
        &["consumer", "mechanism", "sim latency p50", "staleness bound", "allowed if geo-fenced"],
    );
    for region in ["eastus", "westus", "westeurope", "southeastasia"] {
        for (label, access) in [("cross-region", &cross_only), ("replica", &with_replicas)] {
            let mut rng = Rng::new(4);
            let mut latencies: Vec<u64> = Vec::new();
            let m = bench.run(&format!("{region}/{label}"), 1.0, || {
                let out =
                    access.lookup(region, "t", rng.below(entities), 5_000, &eventual).unwrap();
                latencies.push(out.latency_us);
                out
            });
            let _ = m;
            latencies.sort();
            let p50 = latencies[latencies.len() / 2];
            let mech = access.route(region);
            table.row(&[
                region.to_string(),
                format!("{mech:?}"),
                format!("{:.1}ms", p50 as f64 / 1_000.0),
                if mech == AccessMechanism::Replica {
                    format!("≤{lag}s")
                } else {
                    "0s".into()
                },
                if label == "cross-region" { "yes".into() } else { "no (data leaves region)".into() },
            ]);
        }
    }
    table.print();

    // ---- E-GEO a: policy-routed batched reads --------------------------
    // A fresh write sits unapplied in the fabric log (appended at 5000,
    // read at 5030 → 30 s of log-position staleness), so each policy
    // routes differently against the SAME fabric state.
    let covered_token = fabric.token(); // the already-applied prefix
    home.merge("t", &[FeatureRecord::new(7, 3_000, 5_000, vec![777.0])], 5_000);
    let fresh_token =
        fabric.append("t", &[FeatureRecord::new(7, 3_000, 5_000, vec![777.0])], 5_000).unwrap();
    let now = 5_030;
    let keys: Vec<u64> = (0..256).collect();
    let policies: Vec<(&str, ReadConsistency)> = vec![
        ("strong", ReadConsistency::Strong),
        ("bounded(300s) — within", ReadConsistency::BoundedStaleness(300)),
        ("bounded(5s) — exceeded", ReadConsistency::BoundedStaleness(5)),
        ("RYW — token covered", ReadConsistency::ReadYourWrites(covered_token)),
        ("RYW — token uncovered", ReadConsistency::ReadYourWrites(fresh_token)),
    ];
    let mut t2 = Table::new(
        "E-GEO a: policy-routed 256-key batched reads from westeurope",
        &["policy", "mechanism", "batch p50", "per-key p50", "staleness"],
    );
    for (label, policy) in &policies {
        let mut latencies: Vec<u64> = Vec::new();
        let mut stale = 0i64;
        let mut mech = AccessMechanism::Local;
        bench.run(&format!("egeo-a/{label}"), keys.len() as f64, || {
            let out = with_replicas.lookup_many("westeurope", "t", &keys, now, policy).unwrap();
            latencies.push(out.latency_us);
            stale = out.staleness_secs;
            mech = out.mechanism;
            out
        });
        latencies.sort();
        let p50 = latencies[latencies.len() / 2];
        t2.row(&[
            label.to_string(),
            format!("{mech:?}"),
            format!("{:.1}ms", p50 as f64 / 1_000.0),
            format!("{:.1}µs", p50 as f64 / keys.len() as f64),
            format!("{stale}s"),
        ]);
        // Shape guards: Strong/uncovered-RYW/exceeded-bound must cross,
        // within-bound and covered-RYW must serve locally.
        match *label {
            "strong" | "bounded(5s) — exceeded" | "RYW — token uncovered" => {
                assert_eq!(mech, AccessMechanism::CrossRegion, "{label}")
            }
            _ => assert_eq!(mech, AccessMechanism::Replica, "{label}"),
        }
    }
    t2.print();

    // ---- E-GEO b: apply throughput vs replica-region count -------------
    let batches = 64usize;
    let per_batch = 64usize;
    let mut t3 = Table::new(
        "E-GEO b: fabric apply throughput (append → pump to drain) vs region count",
        &["replica regions", "records/pump", "apply throughput", "converged"],
    );
    for k in 1..=3usize {
        let stores: Vec<Arc<OnlineStore>> = (0..k).map(|_| Arc::new(OnlineStore::new(16))).collect();
        let f = ReplicationFabric::new(
            4,
            stores
                .iter()
                .enumerate()
                .map(|(i, s)| (format!("r{i}"), s.clone(), 0))
                .collect(),
            None,
        );
        let mut rng = Rng::new(9);
        let total = (batches * per_batch * k) as f64;
        let m = bench.run(&format!("egeo-b/{k}-regions"), total, || {
            for b in 0..batches {
                let recs: Vec<FeatureRecord> = (0..per_batch)
                    .map(|i| {
                        let e = rng.below(4_096);
                        FeatureRecord::new(e, b as i64, b as i64 + 1, vec![i as f32])
                    })
                    .collect();
                f.append(&format!("t{}", b % 4), &recs, 0).unwrap();
            }
            let applied: u64 = f.pump(1_000).values().sum();
            f.truncate_applied();
            applied
        });
        // Agreement guard: every region drained the whole log.
        let converged = (0..k).all(|i| f.backlog(&format!("r{i}")) == 0);
        assert!(converged, "region backlog must drain");
        t3.row(&[
            k.to_string(),
            format!("{}", batches * per_batch * k),
            fmt_rate(m.throughput()),
            "yes".into(),
        ]);
    }
    t3.print();

    println!(
        "\nShape check (paper §4.1.2): replication wins tail latency everywhere\n\
         outside the home region (local ~0.5ms vs 60–220ms WAN RTT) but is\n\
         staleness-bounded and barred for geo-fenced stores; Strong (and any\n\
         policy a lagging replica cannot satisfy) falls back to one WAN RTT\n\
         with staleness 0. Apply throughput scales with region count: one\n\
         shared log entry fans out to k per-region cursor applies."
    );
}
