//! Experiments E2–E3 (§4.5.3–§4.5.4): Algorithm 2 merge throughput into
//! each sink, and the cost of eventual consistency under injected
//! failures + retries.

use std::sync::Arc;

use geofs::benchkit::{fmt_rate, Bencher, Table};
use geofs::exec::RetryPolicy;
use geofs::materialize::merge::{DualStoreMerger, FaultInjector};
use geofs::metadata::assets::MaterializationPolicy;
use geofs::offline_store::OfflineStore;
use geofs::online_store::OnlineStore;
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;
use geofs::util::Clock;

fn batch(rng: &mut Rng, n: usize, entities: u64) -> Vec<FeatureRecord> {
    (0..n)
        .map(|_| {
            let e = rng.below(entities);
            let ev = rng.range(0, 100_000);
            FeatureRecord::new(e, ev, ev + rng.range(1, 1_000), vec![1.0; 5])
        })
        .collect()
}

fn main() {
    let bench = Bencher::new();

    let mut t1 = Table::new(
        "E2: Algorithm 2 merge throughput (10k-record job batches)",
        &["sink", "mean/batch", "records/s"],
    );
    let n = 10_000;
    {
        let mut rng = Rng::new(1);
        let rows = batch(&mut rng, n, 5_000);
        let off = OfflineStore::new();
        let m = bench.run("offline insert-if-absent", n as f64, || off.merge("t", &rows));
        t1.row(&[m.name.clone(), geofs::benchkit::fmt_ns(m.mean_ns()), fmt_rate(m.throughput())]);
    }
    {
        let mut rng = Rng::new(2);
        let rows = batch(&mut rng, n, 5_000);
        let on = OnlineStore::new(16);
        let m = bench.run("online latest-wins", n as f64, || on.merge("t", &rows, 0));
        t1.row(&[m.name.clone(), geofs::benchkit::fmt_ns(m.mean_ns()), fmt_rate(m.throughput())]);
    }
    {
        // Dual-sink (the real materialization path).
        let mut rng = Rng::new(3);
        let rows = batch(&mut rng, n, 5_000);
        let merger = DualStoreMerger::new(
            Arc::new(OfflineStore::new()),
            Arc::new(OnlineStore::new(16)),
            FaultInjector::none(),
            RetryPolicy::default(),
            Clock::fixed(0),
        );
        let m = bench.run("dual (offline→online)", n as f64, || {
            merger.merge("t", &rows, &MaterializationPolicy::default(), 0).unwrap()
        });
        t1.row(&[m.name.clone(), geofs::benchkit::fmt_ns(m.mean_ns()), fmt_rate(m.throughput())]);
    }
    t1.print();

    let mut t2 = Table::new(
        "E3: eventual consistency under injected faults (per-sink retry to success)",
        &["fault p (each sink)", "mean/batch", "effective records/s", "avg attempts"],
    );
    for &p in &[0.0, 0.1, 0.3, 0.5] {
        let merger = DualStoreMerger::new(
            Arc::new(OfflineStore::new()),
            Arc::new(OnlineStore::new(16)),
            FaultInjector::with_rates(7, p, p),
            RetryPolicy { max_attempts: 64, ..Default::default() },
            Clock::fixed(0),
        );
        let mut rng = Rng::new(4);
        let rows = batch(&mut rng, 2_000, 2_000);
        let mut attempts = 0u64;
        let mut runs = 0u64;
        let m = bench.run(&format!("p={p}"), 2_000.0, || {
            let rep = merger.merge("t", &rows, &MaterializationPolicy::default(), 0).unwrap();
            attempts += (rep.offline_attempts + rep.online_attempts) as u64;
            runs += 1;
            rep
        });
        t2.row(&[
            format!("{p:.1}"),
            geofs::benchkit::fmt_ns(m.mean_ns()),
            fmt_rate(m.throughput()),
            format!("{:.2}", attempts as f64 / (2 * runs.max(1)) as f64),
        ]);
    }
    t2.print();

    println!(
        "\nShape check: merge work scales with retry count ≈ 1/(1-p) per sink;\n\
         correctness (idempotent offline, latest-wins online) is unaffected —\n\
         §4.5.4's \"eventual consistency with job retries\"."
    );
}
