//! Experiment E-NRT: streaming ingestion vs batch materialization.
//!
//! Three measurements over identical event sets:
//!
//! * **throughput** — events/sec through the full streaming plane
//!   (append → watermark → Alg 1 → dual-write), 1 partition vs 4
//!   partitions fanned on a 4-worker pool, against the batch path
//!   (one `Materializer::calculate` over the whole window + one dual
//!   merge) as the baseline.
//! * **ingest→visible latency** — wall time from appending a bin's
//!   events to their derived record being readable in the online store
//!   (the "milliseconds instead of a scheduler period" claim).
//! * **freshness** — the watermark lag the monitor would report.
//!
//! Before timing anything, the bench asserts the streamed online state
//! equals the batch-materialized online state (the differential
//! guarantee), so a perf run doubles as a correctness check.

use std::sync::Arc;

use geofs::benchkit::{fmt_ns, fmt_rate, Bencher, Table};
use geofs::exec::ThreadPool;
use geofs::materialize::Materializer;
use geofs::metadata::assets::{FeatureSetSpec, SourceSpec};
use geofs::monitor::freshness::FreshnessTracker;
use geofs::monitor::metrics::MetricsRegistry;
use geofs::offline_store::OfflineStore;
use geofs::online_store::OnlineStore;
use geofs::source::Event;
use geofs::stream::{StreamConfig, StreamDeps, StreamEvent, StreamIngestor};
use geofs::testkit::FixedSource;
use geofs::types::time::{Granularity, HOUR};
use geofs::types::{EntityInterner, FeatureWindow, Timestamp};
use geofs::util::rng::Rng;
use geofs::util::Clock;

fn spec() -> FeatureSetSpec {
    FeatureSetSpec::rolling("txn", 1, "customer", SourceSpec::synthetic(0), Granularity(HOUR), 4)
}

/// Mostly-ordered event stream + per-entity punctuation that pushes the
/// watermark past the whole data window.
fn gen_events(n: usize, entities: u64, span_hours: i64) -> Vec<StreamEvent> {
    let mut rng = Rng::new(42);
    let span = span_hours * HOUR;
    let mut out: Vec<StreamEvent> = (0..n as u64)
        .map(|seq| {
            let base = (seq as i64 * span) / n as i64;
            let ts = (base + rng.range(-HOUR, HOUR)).clamp(0, span - 1);
            StreamEvent::new(seq, format!("cust_{:04}", rng.below(entities)), ts, rng.f32())
        })
        .collect();
    for e in 0..entities {
        out.push(StreamEvent::new(n as u64 + e, format!("cust_{e:04}"), (span_hours + 1) * HOUR, 0.0));
    }
    out
}

fn deps(
    materializer: Arc<Materializer>,
    clock: Clock,
    pool: Option<Arc<ThreadPool>>,
) -> (StreamDeps, Arc<OfflineStore>, Arc<OnlineStore>) {
    let offline = Arc::new(OfflineStore::new());
    let online = Arc::new(OnlineStore::new(8));
    let d = StreamDeps {
        materializer,
        offline: offline.clone(),
        online: online.clone(),
        freshness: Arc::new(FreshnessTracker::new()),
        metrics: Arc::new(MetricsRegistry::new()),
        clock,
        pool,
        fabric: None,
        checkpoints: None,
        tracer: None,
    };
    (d, offline, online)
}

/// Run the full streaming plane over `events`; returns the online sink.
fn run_stream(
    materializer: &Arc<Materializer>,
    events: &[StreamEvent],
    partitions: usize,
    pool: Option<Arc<ThreadPool>>,
    now: Timestamp,
) -> (Arc<OnlineStore>, Option<Timestamp>) {
    let clock = Clock::fixed(now);
    let (d, _offline, online) = deps(materializer.clone(), clock, pool);
    let ing = StreamIngestor::new(
        spec(),
        StreamConfig { partitions, ..Default::default() },
        d,
    )
    .unwrap();
    ing.ingest(events).unwrap();
    let stats = ing.drain().unwrap();
    (online, stats.watermark)
}

/// The batch path: one Alg 1 calculate over the whole window + one dual
/// merge (scheduler overhead excluded — this is the compute+merge core).
fn run_batch(
    materializer: &Arc<Materializer>,
    source: &FixedSource,
    span_hours: i64,
    now: Timestamp,
) -> (Arc<OfflineStore>, Arc<OnlineStore>) {
    let offline = Arc::new(OfflineStore::new());
    let online = Arc::new(OnlineStore::new(8));
    let window = FeatureWindow::new(0, (span_hours + 1) * HOUR);
    let records = materializer.calculate(&spec(), source, window, now, now).unwrap();
    offline.merge("txn:1", &records);
    online.merge("txn:1", &records, now);
    (offline, online)
}

fn online_state(store: &OnlineStore, now: Timestamp) -> Vec<(u64, Timestamp, Vec<f32>)> {
    store
        .dump_table("txn:1", now)
        .into_iter()
        .map(|r| (r.entity, r.event_ts, r.values.to_vec()))
        .collect()
}

fn main() {
    let fast = std::env::var("GEOFS_BENCH_FAST").is_ok();
    let (n, entities, span_hours) = if fast { (2_000, 32, 24) } else { (20_000, 128, 48) };
    let now = (span_hours + 10) * HOUR;
    // One shared interner/materializer: both paths produce identical
    // entity ids, so states compare directly.
    let materializer = Arc::new(Materializer::new(None, Arc::new(EntityInterner::new())));
    let events = gen_events(n, entities, span_hours);
    let uniques: Vec<Event> = events
        .iter()
        .filter(|e| e.ts < span_hours * HOUR) // punctuation stays out of the batch window
        .map(|e| Event { key: e.key.clone(), ts: e.ts, value: e.value })
        .collect();
    let source = FixedSource(uniques);

    // Agreement guard: streamed ≡ batch online state before timing.
    let (stream_online, wm) = run_stream(&materializer, &events, 4, None, now);
    let (_, batch_online) = run_batch(&materializer, &source, span_hours, now);
    assert_eq!(
        online_state(&stream_online, now + 1),
        online_state(&batch_online, now + 1),
        "streamed online state must equal batch-materialized state"
    );
    let lag = wm.map(|w| now - w).unwrap_or(i64::MAX);
    println!(
        "agreement: OK ({} events, {} entities, {}h span; final watermark lag {}s)",
        events.len(),
        entities,
        span_hours,
        lag
    );

    let b = Bencher::new();
    let pool = Arc::new(ThreadPool::new(4));
    let units = events.len() as f64;

    let m_stream1 = b.run("stream 1p", units, || run_stream(&materializer, &events, 1, None, now));
    let m_stream4 = b.run("stream 4p+pool", units, || {
        run_stream(&materializer, &events, 4, Some(pool.clone()), now)
    });
    let m_batch = b.run("batch calc+merge", units, || {
        run_batch(&materializer, &source, span_hours, now)
    });

    // Ingest→visible: one fresh bin of events + punctuation through a
    // persistent engine; the iteration time IS the ingest-to-visible
    // latency for that bin.
    let clock = Clock::fixed(now);
    let (d, _off, online) = deps(materializer.clone(), clock, None);
    // Bounded retention: the persistent engine must not accumulate every
    // past iteration's events in its buffer (no late events here).
    let ing = StreamIngestor::new(
        spec(),
        StreamConfig { partitions: 1, retention_secs: 24 * HOUR, ..Default::default() },
        d,
    )
    .unwrap();
    let batch_size = 64u64;
    let mut cursor_hour: i64 = 0;
    let mut seq: u64 = 1_000_000;
    let mut rng = Rng::new(7);
    let m_visible = b.run("ingest→visible (64-event bin)", batch_size as f64, || {
        let t0 = cursor_hour * HOUR;
        let batch: Vec<StreamEvent> = (0..batch_size)
            .map(|i| {
                StreamEvent::new(
                    seq + i,
                    format!("cust_{:04}", rng.below(32)),
                    t0 + rng.range(0, HOUR),
                    1.0,
                )
            })
            .chain(std::iter::once(StreamEvent::new(
                seq + batch_size,
                "cust_0000".to_string(),
                t0 + HOUR,
                0.0,
            )))
            .collect();
        seq += batch_size + 1;
        cursor_hour += 1;
        ing.ingest(&batch).unwrap();
        ing.drain().unwrap();
        std::hint::black_box(online.len());
    });

    let mut t = Table::new(
        "E-NRT — streaming ingestion vs batch materialization",
        Table::LATENCY_HEADERS,
    );
    t.latency_row(&m_stream1);
    t.latency_row(&m_stream4);
    t.latency_row(&m_batch);
    t.latency_row(&m_visible);
    t.print();

    println!(
        "\ningest→visible p50 {} (events become servable {} after append; batch path waits a scheduler period)",
        fmt_ns(m_visible.p50_ns() as f64),
        fmt_ns(m_visible.p50_ns() as f64),
    );
    println!(
        "throughput: stream 1p {}  stream 4p {}  batch {}",
        fmt_rate(m_stream1.throughput()),
        fmt_rate(m_stream4.throughput()),
        fmt_rate(m_batch.throughput()),
    );
}
