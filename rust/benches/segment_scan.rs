//! Experiment E-SEG (offline storage engine, PR 4): compressed columnar
//! segments + background size-tiered compaction.
//!
//! Three questions, matching the acceptance bar for the rebuild:
//!
//! 1. **Compression ratio** — encoded bytes (delta/dod varint keys,
//!    dict/fixed value planes, block directory, bloom) vs the raw v2
//!    plane layout, on a realistic regular-cadence table.
//! 2. **Scan + PIT throughput on compressed segments** — full-window
//!    scans and merge-join training frames read through lazy block
//!    decode must stay within noise of (or beat, being
//!    bandwidth-bound) an uncompressed `Vec<FeatureRecord>` baseline;
//!    a cross-engine agreement guard (merge-join ≡ naive oracle) runs
//!    on the compressed store before anything is timed.
//! 3. **Merge latency vs segment count** — with inline compaction gone,
//!    writer `merge` cost must stay flat as sealed segments accumulate,
//!    and the background `CompactionDriver` must bound the segment
//!    count without showing up in writer latency.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use geofs::benchkit::{fmt_ns, fmt_rate, Bencher, Table};
use geofs::metadata::assets::{FeatureSetSpec, SourceSpec};
use geofs::offline_store::{CompactionDriver, OfflineStore, StoreConfig};
use geofs::query::offline::{naive_training_frame, OfflineQueryEngine};
use geofs::query::pit::{Observation, PitConfig};
use geofs::query::spec::FeatureRef;
use geofs::types::time::{Granularity, DAY};
use geofs::types::{FeatureRecord, FeatureWindow};
use geofs::util::rng::Rng;

fn rows(entities: u64, days: i64) -> Vec<FeatureRecord> {
    let mut out = Vec::new();
    for d in 1..=days {
        for e in 0..entities {
            out.push(FeatureRecord::new(
                e,
                d * DAY,
                d * DAY + 600,
                // Two low-cardinality columns + three per-entity ones —
                // the shape real feature tables have.
                vec![1.0, 0.0, d as f32, e as f32, (e % 7) as f32],
            ));
        }
    }
    out
}

fn specs() -> HashMap<String, FeatureSetSpec> {
    let mut m = HashMap::new();
    m.insert(
        "txn".to_string(),
        FeatureSetSpec::rolling("txn", 1, "customer", SourceSpec::synthetic(0), Granularity::daily(), 30),
    );
    m
}

fn main() {
    let fast = std::env::var("GEOFS_BENCH_FAST").is_ok();
    let bench = Bencher::new();

    // ---- 1 + 2: compression ratio and scan/PIT throughput -------------
    let mut t1 = Table::new(
        "E-SEG a: compressed segments — size and scan throughput vs raw rows",
        &["store rows", "bytes/row raw", "bytes/row enc", "ratio", "path", "mean", "rows/s"],
    );
    let sizes: &[(u64, i64)] = if fast { &[(200, 30)] } else { &[(200, 30), (1_000, 60), (2_000, 90)] };
    for &(entities, days) in sizes {
        let raw_rows = rows(entities, days);
        let n = raw_rows.len();
        let store = Arc::new(OfflineStore::new());
        store.merge("txn:1", &raw_rows);
        store.compact("txn:1"); // one sealed segment, like a settled table
        let (enc, raw) = store.encoded_bytes("txn:1");
        let window = FeatureWindow::new(0, (days + 1) * DAY);

        // Cross-engine agreement guard on compressed segments: the
        // merge-join over block-decoded cursors must equal the naive
        // oracle before anything is timed.
        let engine = OfflineQueryEngine::new(store.clone());
        let sp = specs();
        let features =
            vec![FeatureRef::parse("txn:1:720h_sum").unwrap(), FeatureRef::parse("txn:1:720h_cnt").unwrap()];
        let mut rng = Rng::new(17);
        let obs: Vec<Observation> = (0..if fast { 50 } else { 400 })
            .map(|_| Observation { entity: rng.below(entities + 2), ts: rng.range(DAY, days * DAY) })
            .collect();
        let cfg = PitConfig::default();
        let frame = engine.get_training_frame(&obs, &features, &sp, cfg).unwrap();
        let oracle = naive_training_frame(&store, &obs, &features, &sp, cfg).unwrap();
        assert_eq!(frame, oracle, "compressed merge-join must agree with the oracle");

        let m_comp = bench.run("compressed scan", n as f64, || store.scan("txn:1", window));
        let m_raw = bench.run("raw-vec scan", n as f64, || {
            raw_rows
                .iter()
                .filter(|r| window.contains(r.event_ts))
                .cloned()
                .collect::<Vec<FeatureRecord>>()
        });
        let m_pit = bench.run("merge-join frame", obs.len() as f64, || {
            engine.get_training_frame(&obs, &features, &sp, cfg).unwrap()
        });
        for m in [&m_comp, &m_raw] {
            t1.row(&[
                n.to_string(),
                format!("{:.1}", raw as f64 / n as f64),
                format!("{:.1}", enc as f64 / n as f64),
                format!("{:.2}x", raw as f64 / enc as f64),
                m.name.clone(),
                fmt_ns(m.mean_ns()),
                fmt_rate(m.throughput()),
            ]);
        }
        t1.row(&[
            n.to_string(),
            String::new(),
            String::new(),
            String::new(),
            format!("{} ({} obs)", m_pit.name, obs.len()),
            fmt_ns(m_pit.mean_ns()),
            fmt_rate(m_pit.throughput()),
        ]);
    }
    t1.print();

    // ---- 3: merge latency vs segment count ----------------------------
    // Each 512-row batch fills the delta exactly, so every merge seals
    // one segment: segment count == merges so far. Without a driver the
    // tiers accumulate; writer latency must not care.
    let mut t2 = Table::new(
        "E-SEG b: writer merge latency vs sealed-segment count (spill=512)",
        &["scenario", "segments at sample", "merges", "mean merge", "p99-ish max"],
    );
    let total_batches = if fast { 24 } else { 96 };
    let batch_rows = 512usize;
    let mk_batch = |k: usize| -> Vec<FeatureRecord> {
        (0..batch_rows)
            .map(|i| {
                let row = (k * batch_rows + i) as i64;
                FeatureRecord::new((row % 31) as u64, row * 10, row * 10 + 5, vec![1.0, row as f32])
            })
            .collect()
    };
    let buckets: &[(usize, usize)] = &[(0, 8), (8, 32), (32, usize::MAX)];
    for driver_on in [false, true] {
        let store = Arc::new(OfflineStore::with_config(StoreConfig {
            spill_rows: batch_rows,
            tier_fanin: 4,
            ..Default::default()
        }));
        let driver = driver_on
            .then(|| CompactionDriver::spawn(store.clone(), std::time::Duration::from_millis(1)));
        // (segment count before merge, merge ns)
        let mut samples: Vec<(usize, u64)> = Vec::new();
        for k in 0..total_batches {
            let batch = mk_batch(k);
            let segs = store.storage_shape("txn:1").0;
            let t0 = Instant::now();
            store.merge("txn:1", &batch);
            samples.push((segs, t0.elapsed().as_nanos() as u64));
        }
        // Settle before reading the reported shape: drop joins the
        // driver thread, and draining the remaining ticks makes the
        // "final segs" figure deterministic instead of whatever instant
        // the race landed on.
        if let Some(d) = driver {
            drop(d);
            while store.compact_tick() > 0 {}
            assert_eq!(store.row_count("txn:1"), (total_batches * batch_rows) as u64);
        }
        let final_shape = store.storage_shape("txn:1").0;
        for &(lo, hi) in buckets {
            let in_bucket: Vec<u64> =
                samples.iter().filter(|(s, _)| *s >= lo && *s < hi).map(|&(_, ns)| ns).collect();
            if in_bucket.is_empty() {
                continue;
            }
            let mean = in_bucket.iter().sum::<u64>() as f64 / in_bucket.len() as f64;
            let max = *in_bucket.iter().max().unwrap();
            t2.row(&[
                if driver_on { format!("background driver (final segs {final_shape})") } else { "no compaction".into() },
                if hi == usize::MAX { format!("{lo}+") } else { format!("{lo}–{hi}") },
                in_bucket.len().to_string(),
                fmt_ns(mean),
                fmt_ns(max as f64),
            ]);
        }
    }
    t2.print();

    println!(
        "\nShape check: encoded bytes/row lands well under the 28-byte raw key\n\
         plane + values (delta-of-delta keys ≈ 3–5 bytes/row at daily cadence,\n\
         dict planes collapse low-cardinality columns); compressed scans stay\n\
         within noise of the raw-vector baseline because block decode trades\n\
         against memory bandwidth; and mean merge latency is flat across the\n\
         segment-count buckets — the background driver, not the writer, pays\n\
         for tier folding. See EXPERIMENTS.md §E-SEG for recording results."
    );
}
