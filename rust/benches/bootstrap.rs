//! Experiment E8 (§4.5.5): bootstrapping a late-enabled online store from
//! the offline store vs re-running backfill against the source.

use geofs::benchkit::{fmt_rate, Bencher, Table};
use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::materialize::bootstrap_offline_to_online;
use geofs::online_store::OnlineStore;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::DAY;
use geofs::types::FeatureWindow;

fn main() {
    let bench = Bencher::new();
    let days = 30i64;

    // Build an offline-only history once (offline-first deployment).
    let fs = FeatureStore::open(Config::default_local(), OpenOptions::default())
        .expect("run `make artifacts` first");
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 256, days, seed: 21, ..Default::default() },
    )
    .unwrap();
    fs.clock.set(days * DAY);
    fs.backfill(&w.txn_table, FeatureWindow::new(0, days * DAY)).unwrap();
    let offline_rows = fs.offline.row_count(&w.txn_table);

    let mut table = Table::new(
        "E8: enabling the online store after 30 days of offline-only history",
        &["method", "mean", "entities online", "source re-read?"],
    );

    // Option A (§4.5.5): bootstrap from the offline store.
    let mut entities = 0;
    let m_boot = bench.run("bootstrap offline→online", 1.0, || {
        let online = OnlineStore::new(16);
        let stats = bootstrap_offline_to_online(&fs.offline, &online, &w.txn_table, fs.clock.now());
        entities = stats.inserted;
        online
    });
    table.row(&[
        m_boot.name.clone(),
        geofs::benchkit::fmt_ns(m_boot.mean_ns()),
        entities.to_string(),
        "no".into(),
    ]);

    // Option B (the paper's strawman): re-run the whole backfill with the
    // online sink enabled — recompute everything from source.
    let mut entities_b = 0u64;
    let m_back = bench.run("re-backfill from source", 1.0, || {
        let (fs2, w2) = {
            let fs2 = FeatureStore::open(Config::default_local(), OpenOptions::default()).unwrap();
            let w2 = ChurnWorkload::install(
                &fs2,
                ChurnWorkloadConfig { customers: 256, days, seed: 21, ..Default::default() },
            )
            .unwrap();
            (fs2, w2)
        };
        fs2.clock.set(days * DAY);
        fs2.backfill(&w2.txn_table, FeatureWindow::new(0, days * DAY)).unwrap();
        entities_b = fs2.online.len() as u64;
        fs2
    });
    table.row(&[
        m_back.name.clone(),
        geofs::benchkit::fmt_ns(m_back.mean_ns()),
        entities_b.to_string(),
        "yes (full recompute)".into(),
    ]);
    table.print();

    let speedup = m_back.mean_ns() / m_boot.mean_ns();
    println!(
        "\noffline rows: {offline_rows}; bootstrap is {speedup:.0}x cheaper than\n\
         re-backfill and needs no source data (which \"may not exist already for\n\
         the early times\" — §4.5.5's first downside). Throughput: {}",
        fmt_rate(offline_rows as f64 * 1e9 / m_boot.mean_ns())
    );
}
