//! Experiment E-OBS: observability overhead on the serving hot path.
//!
//! The tentpole claim of the observability PR is that the metrics core
//! and the request tracer cost (almost) nothing when they are not
//! looking: `inc`/`observe` are striped relaxed atomics, and an
//! unsampled request's only tracing cost is one field compare. This
//! bench pins that claim against the E9f read workload (point reads and
//! 256-key batches through the admitted serving path) across four
//! tracer modes:
//!
//! * `untraced`     — no tracer wired at all (the PR-7 baseline shape);
//! * `sampling-off` — tracer wired, `sample_every: 0`;
//! * `1-in-64`      — the load harness's default sampling rate;
//! * `always-on`    — every request builds a full span tree.
//!
//! Acceptance guard (asserted, not eyeballed): the `sampling-off`
//! point-read p99 stays within 1.1× of `untraced`. Runs are interleaved
//! best-of-N so one noisy scheduling quantum can't fail the guard.

use std::sync::Arc;

use geofs::benchkit::{fmt_ns, fmt_rate, Bencher, Table};
use geofs::geo::access::{CrossRegionAccess, ReadConsistency};
use geofs::geo::topology::GeoTopology;
use geofs::monitor::metrics::MetricsRegistry;
use geofs::monitor::trace::{TraceConfig, Tracer};
use geofs::online_store::OnlineStore;
use geofs::serving::router::{RouteTable, ServingRouter};
use geofs::serving::service::OnlineServing;
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

const ENTITIES: u64 = 100_000;
const BATCH: usize = 256;
const REPS: usize = 3;

fn serving_with(trace: Option<TraceConfig>) -> OnlineServing {
    let store = Arc::new(OnlineStore::new(16));
    let recs: Vec<FeatureRecord> = (0..ENTITIES)
        .map(|i| FeatureRecord::new(i, 1_000, 2_000, vec![i as f32; 5]))
        .collect();
    store.merge("t", &recs, 2_000);
    let routes = Arc::new(RouteTable::new());
    routes.set(
        "t",
        Arc::new(CrossRegionAccess {
            topology: Arc::new(GeoTopology::default_four_region()),
            home_region: "eastus".into(),
            home_store: store,
            fabric: None,
            geo_fenced: false,
        }),
    );
    let mut s = OnlineServing::new(ServingRouter::new(routes), Arc::new(MetricsRegistry::new()));
    s.tracer = trace.map(Tracer::new);
    s
}

fn main() {
    let bench = Bencher::new();
    let modes: [(&str, Option<TraceConfig>); 4] = [
        ("untraced", None),
        ("sampling-off", Some(TraceConfig { sample_every: 0, ..Default::default() })),
        ("1-in-64", Some(TraceConfig { sample_every: 64, ..Default::default() })),
        ("always-on", Some(TraceConfig { sample_every: 1, ..Default::default() })),
    ];
    let servings: Vec<(&str, OnlineServing)> =
        modes.into_iter().map(|(name, cfg)| (name, serving_with(cfg))).collect();
    let consistency = ReadConsistency::default();

    // Interleaved best-of-N: rep-major order so every mode sees the same
    // machine conditions, then the per-mode minimum p99 damps outliers.
    let mut point_p99 = [u64::MAX; 4];
    let mut point_best: Vec<Option<geofs::benchkit::Measurement>> = vec![None; 4];
    let mut batch_best: Vec<Option<geofs::benchkit::Measurement>> = vec![None; 4];
    for rep in 0..REPS {
        for (mi, (name, s)) in servings.iter().enumerate() {
            let mut rng = Rng::new(7 + rep as u64);
            let m = bench.run(&format!("E-OBS point {name} rep{rep}"), 1.0, || {
                let key = [rng.below(ENTITIES)];
                std::hint::black_box(
                    s.lookup_batch_admitted("bench", "t", &key, "eastus", 3_000, &consistency),
                )
                .is_ok()
            });
            if m.p99_ns() < point_p99[mi] {
                point_p99[mi] = m.p99_ns();
                point_best[mi] = Some(m);
            }
            let mut rng = Rng::new(70 + rep as u64);
            let key_sets: Vec<Vec<u64>> =
                (0..32).map(|_| (0..BATCH).map(|_| rng.below(ENTITIES)).collect()).collect();
            let mut k = 0usize;
            let m = bench.run(&format!("E-OBS batch {name} rep{rep}"), BATCH as f64, || {
                k = (k + 1) % key_sets.len();
                std::hint::black_box(
                    s.lookup_batch_admitted(
                        "bench",
                        "t",
                        &key_sets[k],
                        "eastus",
                        3_000,
                        &consistency,
                    ),
                )
                .is_ok()
            });
            match &batch_best[mi] {
                Some(b) if b.p99_ns() <= m.p99_ns() => {}
                _ => batch_best[mi] = Some(m),
            }
        }
    }

    let mut t = Table::new(
        &format!("E-OBS: tracer mode overhead, admitted read path (best of {REPS})"),
        &["mode", "op", "p50", "p99", "lookups/s"],
    );
    for (mi, (name, _)) in servings.iter().enumerate() {
        for (op, m) in
            [("point", point_best[mi].as_ref().unwrap()), ("256-key batch", batch_best[mi].as_ref().unwrap())]
        {
            t.row(&[
                name.to_string(),
                op.into(),
                fmt_ns(m.p50_ns() as f64),
                fmt_ns(m.p99_ns() as f64),
                fmt_rate(m.throughput()),
            ]);
        }
    }
    t.print();

    // Sanity: always-on really traced — its tracer has completed spans.
    let traced = servings[3].1.tracer.as_ref().unwrap().recent();
    assert!(!traced.is_empty(), "always-on mode produced no traces");
    println!("\nsample always-on trace:\n{}", traced[0].render());

    // Acceptance guard: a wired-but-off tracer costs one field compare,
    // so its point-read p99 must stay within 1.1x of no tracer at all.
    let ratio = point_p99[1] as f64 / point_p99[0].max(1) as f64;
    println!(
        "E-OBS guard: sampling-off point p99 = {:.3}x untraced p99 ({} vs {})",
        ratio,
        fmt_ns(point_p99[1] as f64),
        fmt_ns(point_p99[0] as f64),
    );
    assert!(
        ratio <= 1.1,
        "sampling-off tracing must keep point-read p99 within 1.1x of untraced, got {ratio:.3}x"
    );
}
