//! Experiment E-LOAD: production load harness over the fully-wired store
//! (geo-replication + compaction drivers live, streaming engine feeding
//! the hourly table) with admission control sized so the final phase
//! saturates the tenant budget.
//!
//! Three phases — steady, write-heavy, read-overload — each reporting
//! per-op-class p50/p99/p999, throughput, and shed rate. The run writes
//! `BENCH_load.json` (override the path with `GEOFS_BENCH_OUT`) so the
//! trajectory is diffable across PRs; CI uploads it as an artifact.
//!
//! Run asserts (the paper's overload claim, checked, not eyeballed):
//! * the pre-overload phases shed nothing — their demand fits inside
//!   the admission burst by construction;
//! * the read-overload phase (≥ 2× saturation) sheds typed
//!   `Overloaded` requests while the p99 of *served* reads stays
//!   bounded — shedding keeps the goodput fast instead of letting the
//!   queue absorb the spike.

use std::path::PathBuf;

use geofs::load::{LoadConfig, LoadHarness};

fn main() {
    let fast = std::env::var("GEOFS_BENCH_FAST").is_ok();
    let cfg = LoadConfig::standard(fast);
    let seed = cfg.seed;
    let harness = LoadHarness::setup(cfg).expect("load harness setup");
    let report = harness.run().expect("load harness run");
    report.print();

    // Overload contract.
    for name in ["steady", "write-heavy"] {
        let phase = report.phase(name);
        for (class, stats) in &phase.classes {
            assert_eq!(stats.shed, 0, "phase '{name}' class '{class}' shed inside the budget");
        }
    }
    let overload = report.phase("read-overload").class("read");
    assert!(overload.shed > 0, "read-overload phase must shed (offered ≥2× the admission burst)");
    let served_p99_ns = overload.hist.quantile(0.99);
    assert!(
        overload.served == 0 || served_p99_ns < 1_000_000_000,
        "served-read p99 unbounded under overload: {served_p99_ns}ns"
    );
    println!(
        "\noverload: shed {} / {} reads ({:.1}%), served-read p99 {}",
        overload.shed,
        overload.issued,
        overload.shed_rate() * 100.0,
        geofs::benchkit::fmt_ns(served_p99_ns as f64),
    );

    let out = std::env::var("GEOFS_BENCH_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("BENCH_load.json"));
    report.write_json(&out).expect("write BENCH_load.json");
    println!("wrote {} (seed {seed})", out.display());

    // Per-phase metrics deltas, as their own artifact next to the main
    // report (override with GEOFS_BENCH_METRICS_OUT).
    let metrics_out = std::env::var("GEOFS_BENCH_METRICS_OUT")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("BENCH_load_metrics.json"));
    report.write_metrics_json(&metrics_out).expect("write BENCH_load_metrics.json");
    println!("wrote {}", metrics_out.display());
}
