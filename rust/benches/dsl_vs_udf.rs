//! Experiment E5 (§3.1.6 "Optimized query execution"): the DSL-optimized
//! plan vs the black-box baselines.
//!
//! Plans compared on identical binned inputs:
//! * `dsl`    — AOT artifact of the fused Pallas one-pass program
//! * `naive`  — AOT artifact of the per-bin `lax.map` + dynamic-slice
//!              recompute plan (what a black-box UDF costs inside XLA)
//! * `rust`   — the in-process Rust UDF recompute (engine bypassed)
//!
//! Methodology: host CPU contention drifts over a bench run by enough to
//! flip verdicts if plans are timed in separate blocks, so the three
//! plans are measured **interleaved** (round-robin, one execution each
//! per round) — drift then affects all plans equally and the ratios are
//! stable even when absolute numbers move.
//!
//! Expected shape (paper's claim): dsl beats naive-HLO at real sizes;
//! the pure-Rust UDF wins only where PJRT dispatch overhead dominates —
//! the crossover is the interesting row.

use std::time::Instant;

use geofs::benchkit::{fmt_ns, fmt_rate, Table};
use geofs::dsl::udf_rolling_recompute;
use geofs::runtime::{BinPlanes, Engine, Variant};
use geofs::util::hist::Histogram;
use geofs::util::rng::Rng;

fn planes(seed: u64, e: usize, t_out: usize, w: usize) -> BinPlanes {
    let mut rng = Rng::new(seed);
    let mut b = BinPlanes::empty(e, t_out + w - 1);
    for ei in 0..e {
        for bi in 0..t_out + w - 1 {
            if rng.bool(0.7) {
                b.add_event(ei, bi, rng.f32() * 10.0);
            }
        }
    }
    b
}

fn main() {
    let engine = Engine::load("artifacts").expect("run `make artifacts` first");
    engine.warmup().expect("artifact warmup");
    let rounds: usize = if std::env::var("GEOFS_BENCH_FAST").is_ok() { 30 } else { 150 };

    let mut table = Table::new(
        "E5: DSL-optimized plan vs black-box UDF plans (rolling aggregation, interleaved)",
        &["workload", "plan", "mean", "p50", "cells/s", "vs dsl"],
    );

    // (label, E, T, W) — windows must exist in the artifact set.
    let cases =
        [("tiny 16x32 w4", 16, 32, 4), ("hourly 64x168 w24", 64, 168, 24), ("daily 256x96 w30", 256, 96, 30)];
    for (label, e, t, w) in cases {
        let p = planes(7, e, t, w);
        let cells = (e * t) as f64;

        // Warmup each plan.
        for _ in 0..3 {
            std::hint::black_box(engine.rolling(Variant::Dsl, &p, w).unwrap());
            std::hint::black_box(engine.rolling(Variant::Naive, &p, w).unwrap());
            std::hint::black_box(udf_rolling_recompute(&p, w));
        }
        // Interleaved measurement.
        let mut h = [Histogram::new(), Histogram::new(), Histogram::new()];
        for _ in 0..rounds {
            let t0 = Instant::now();
            std::hint::black_box(engine.rolling(Variant::Dsl, &p, w).unwrap());
            h[0].record(t0.elapsed().as_nanos() as u64);
            let t0 = Instant::now();
            std::hint::black_box(engine.rolling(Variant::Naive, &p, w).unwrap());
            h[1].record(t0.elapsed().as_nanos() as u64);
            let t0 = Instant::now();
            std::hint::black_box(udf_rolling_recompute(&p, w));
            h[2].record(t0.elapsed().as_nanos() as u64);
        }
        // Medians are the robust statistic under drift spikes.
        let dsl_p50 = h[0].quantile(0.5) as f64;
        for (name, hist) in [("dsl", &h[0]), ("naive", &h[1]), ("rust-udf", &h[2])] {
            let p50 = hist.quantile(0.5) as f64;
            table.row(&[
                label.to_string(),
                name.to_string(),
                fmt_ns(hist.mean()),
                fmt_ns(p50),
                fmt_rate(cells * 1e9 / p50),
                format!("{:.2}x", p50 / dsl_p50),
            ]);
        }
    }
    table.print();

    println!(
        "\nShape check: dsl ≤ naive on p50 at every real workload; rust-udf is\n\
         competitive only when E·T is small (PJRT dispatch overhead dominates) —\n\
         the paper's rationale for optimizing DSL-declared transformations while\n\
         treating UDFs as black boxes."
    );
}
