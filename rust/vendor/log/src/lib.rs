//! Minimal logging facade, API-compatible with the subset of the
//! `log` crate used by this workspace (the real crate is unreachable in
//! the offline build environment).
//!
//! Supported surface: `error!`/`warn!`/`info!`/`debug!`/`trace!`
//! macros, the [`Log`] trait, [`set_logger`]/[`set_max_level`]/
//! [`max_level`], and the [`Level`]/[`LevelFilter`]/[`Metadata`]/
//! [`Record`] types.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

/// Verbosity level of a log record, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

/// Maximum-verbosity filter; `Off` disables logging entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record: its level and target.
#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, borrowed for the duration of the `Log::log` call.
#[derive(Debug, Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
    module_path: Option<&'a str>,
    file: Option<&'a str>,
    line: Option<u32>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
    pub fn module_path(&self) -> Option<&'a str> {
        self.module_path
    }
    pub fn file(&self) -> Option<&'a str> {
        self.file
    }
    pub fn line(&self) -> Option<u32> {
        self.line
    }
}

/// A logging sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Error returned when a logger is installed twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: RwLock<Option<&'static dyn Log>> = RwLock::new(None);
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger. Fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let mut g = LOGGER.write().unwrap();
    if g.is_some() {
        return Err(SetLoggerError(()));
    }
    *g = Some(logger);
    Ok(())
}

pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not part of the public API of the real crate, but
/// called by the macros below.
#[doc(hidden)]
pub fn __private_log(
    args: fmt::Arguments,
    level: Level,
    target: &str,
    module_path: &'static str,
    file: &'static str,
    line: u32,
) {
    let g = LOGGER.read().unwrap();
    if let Some(logger) = *g {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record {
                metadata,
                args,
                module_path: Some(module_path),
                file: Some(file),
                line: Some(line),
            });
        }
    }
}

#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => ({
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_log(
                format_args!($($arg)+),
                lvl,
                $target,
                module_path!(),
                file!(),
                line!(),
            );
        }
    });
    ($lvl:expr, $($arg:tt)+) => ($crate::log!(target: module_path!(), $lvl, $($arg)+));
}

#[macro_export]
macro_rules! error {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Error, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Error, $($arg)+));
}

#[macro_export]
macro_rules! warn {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Warn, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Warn, $($arg)+));
}

#[macro_export]
macro_rules! info {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Info, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Info, $($arg)+));
}

#[macro_export]
macro_rules! debug {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Debug, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Debug, $($arg)+));
}

#[macro_export]
macro_rules! trace {
    (target: $target:expr, $($arg:tt)+) => ($crate::log!(target: $target, $crate::Level::Trace, $($arg)+));
    ($($arg:tt)+) => ($crate::log!($crate::Level::Trace, $($arg)+));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(!(Level::Trace <= LevelFilter::Off));
    }

    #[test]
    fn max_level_roundtrip_and_macros_without_logger() {
        // One test (not several) because max_level is global state and
        // the harness runs tests in parallel.
        set_max_level(LevelFilter::Warn);
        assert_eq!(max_level(), LevelFilter::Warn);
        // No logger installed in this test binary: must be a no-op.
        set_max_level(LevelFilter::Trace);
        info!("hello {}", 42);
        warn!(target: "custom", "warned");
        error!("boom");
        debug!("dbg");
        trace!("trc");
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
