//! Minimal error-aggregation type, API-compatible with the subset of
//! the `anyhow` crate used by this workspace's binaries and examples
//! (the real crate is unreachable in the offline build environment).
//!
//! Supported surface: [`Error`], [`Result`], `anyhow!`, `bail!`, and
//! `?`-conversion from any `std::error::Error + Send + Sync + 'static`.

use std::fmt;

/// A type-erased error with a best-effort source chain in `{:?}`.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

/// `Result<T, anyhow::Error>` alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Wrap a concrete error.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(err: E) -> Self {
        Error(Box::new(err))
    }

    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display + fmt::Debug + Send + Sync + 'static>(message: M) -> Self {
        Error(Box::new(MessageError(message)))
    }

    /// The root cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut next: Option<&(dyn std::error::Error + 'static)> = Some(self.0.as_ref());
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that keeps the blanket `From` below coherent with `From<T> for T`,
// exactly like the real anyhow.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::new(err)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

/// String-message error used by `anyhow!` / `bail!`.
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> std::error::Error for MessageError<M> {}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => ($crate::Error::msg(format!($msg)));
    ($fmt:expr, $($arg:tt)*) => ($crate::Error::msg(format!($fmt, $($arg)*)));
    ($err:expr $(,)?) => ($crate::Error::msg(format!("{}", $err)));
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => (return Err($crate::anyhow!($($arg)*)));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
    }

    #[test]
    fn bail_returns_err() {
        fn inner(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged: {flag}");
            }
            Ok(1)
        }
        assert!(inner(true).is_err());
        assert_eq!(inner(false).unwrap(), 1);
    }

    #[test]
    fn debug_prints_chain() {
        let e = Error::new(io_err());
        let dbg = format!("{e:?}");
        assert!(dbg.contains("disk on fire"));
        assert_eq!(e.chain().count(), 1);
    }
}
