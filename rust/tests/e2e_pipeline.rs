//! End-to-end integration: the full three-layer stack on the churn
//! workload (DESIGN.md E2E / E10), including AOT-artifact execution.

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::query::pit::PitConfig;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::DAY;
use geofs::types::FeatureWindow;

fn open() -> (std::sync::Arc<FeatureStore>, ChurnWorkload) {
    let fs = FeatureStore::open(Config::default_geo(), OpenOptions::default())
        .expect("run `make artifacts` before cargo test");
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 32, days: 8, seed: 3, ..Default::default() },
    )
    .unwrap();
    (fs, w)
}

fn materialize_daily(fs: &FeatureStore, w: &ChurnWorkload, days: i64) {
    for day in 1..=days {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table).unwrap();
        fs.materialize_tick(&w.interactions_table).unwrap();
    }
}

#[test]
fn full_pipeline_materialize_query_serve() {
    let (fs, w) = open();
    materialize_daily(&fs, &w, 8);

    // Offline store has both tables, and coverage matches the ticks.
    assert!(fs.offline.row_count(&w.txn_table) > 0);
    assert!(fs.offline.row_count(&w.interactions_table) > 0);
    assert!(fs.is_materialized(&w.txn_table, FeatureWindow::new(0, 8 * DAY)));
    assert!(!fs.is_materialized(&w.txn_table, FeatureWindow::new(0, 9 * DAY)));

    // Training frame resolves with full fill for active customers.
    let spine = w.observation_spine(200);
    let observations: Vec<(String, i64)> =
        spine.iter().map(|(k, ts, _)| (k.clone(), *ts)).collect();
    let frame = fs
        .get_training_frame(
            &w.principal,
            None,
            &observations,
            &w.model_features(),
            PitConfig::default(),
            "eastus",
        )
        .unwrap();
    assert_eq!(frame.len(), 200);
    assert!(frame.fill_rate() > 0.9, "fill rate {:.3}", frame.fill_rate());

    // Online serving hits for every customer with any history.
    let out = fs.get_online(&w.principal, &w.txn_table, "cust_00000", "eastus").unwrap();
    assert!(out.record.is_some());
    // The online record equals the offline Eq. 2 latest for the entity.
    let latest = fs.offline.latest_per_entity(&w.txn_table);
    let id = fs.interner.lookup("cust_00000").unwrap();
    let off = latest.iter().find(|r| r.entity == id).unwrap();
    assert_eq!(out.record.unwrap().version(), off.version());
}

#[test]
fn incremental_equals_backfill() {
    // The same history materialized (a) incrementally day-by-day and
    // (b) as one backfill must produce identical offline feature values
    // (creation timestamps differ; values must not).
    let (fs_a, w_a) = open();
    materialize_daily(&fs_a, &w_a, 8);

    let (fs_b, w_b) = open();
    fs_b.clock.set(8 * DAY);
    fs_b.backfill(&w_b.txn_table, FeatureWindow::new(0, 8 * DAY)).unwrap();

    let mut rows_a = fs_a.offline.scan(&w_a.txn_table, FeatureWindow::new(0, 9 * DAY));
    let mut rows_b = fs_b.offline.scan(&w_b.txn_table, FeatureWindow::new(0, 9 * DAY));
    // Interners are per-store; compare via resolved keys.
    let key_a: std::collections::HashMap<_, _> = rows_a
        .drain(..)
        .map(|r| ((fs_a.interner.resolve(r.entity).unwrap(), r.event_ts), r.values))
        .collect();
    let key_b: std::collections::HashMap<_, _> = rows_b
        .drain(..)
        .map(|r| ((fs_b.interner.resolve(r.entity).unwrap(), r.event_ts), r.values))
        .collect();
    assert_eq!(key_a.len(), key_b.len());
    for (k, va) in &key_a {
        let vb = &key_b[k];
        assert_eq!(va.len(), vb.len());
        for (a, b) in va.iter().zip(vb.iter()) {
            assert!((a - b).abs() <= 1e-3 + b.abs() * 1e-5, "{k:?}: {a} vs {b}");
        }
    }
}

#[test]
fn online_offline_consistency_after_materialization() {
    // Eq. 2: for every entity, online holds exactly the offline
    // max(event_ts, creation_ts) record.
    let (fs, w) = open();
    materialize_daily(&fs, &w, 6);
    let now = fs.clock.now();
    for rec in fs.offline.latest_per_entity(&w.txn_table) {
        let online = fs.online.get(&w.txn_table, rec.entity, now).unwrap();
        assert_eq!(online.version(), rec.version());
        assert_eq!(online.values, rec.values);
    }
}

#[test]
fn dsl_plan_used_for_registered_sets() {
    // The churn feature sets must plan onto the optimized artifact, not
    // the fallback (guards against silent plan regressions).
    let (fs, _w) = open();
    let specs = fs.feature_set_specs();
    // Re-plan through a fresh materializer view: the plan rationale is
    // surfaced via metrics-free API here — use dsl::plan_transform with
    // the engine manifest.
    let manifest = geofs::runtime::Manifest::load("artifacts").unwrap();
    for spec in specs.values() {
        let plan = geofs::dsl::plan_transform(
            &spec.transform,
            spec.granularity,
            Some(&manifest),
        )
        .unwrap();
        assert!(
            matches!(plan.kind, geofs::dsl::PlanKind::Artifact(geofs::runtime::Variant::Dsl)),
            "{} must use the optimized plan, got {:?}",
            spec.name,
            plan.kind
        );
    }
}

#[test]
fn freshness_sla_and_catchup() {
    let (fs, w) = open();
    materialize_daily(&fs, &w, 4);
    assert!(fs.table_freshness(&w.txn_table).unwrap().within_sla);

    // Fall three days behind → SLA violation; one tick catches up.
    fs.clock.set(7 * DAY);
    assert!(!fs.table_freshness(&w.txn_table).unwrap().within_sla);
    fs.materialize_tick(&w.txn_table).unwrap();
    let f = fs.table_freshness(&w.txn_table).unwrap();
    assert!(f.within_sla, "staleness after catchup: {}", f.staleness_secs);
}

#[test]
fn not_materialized_vs_no_data_distinction() {
    // §4.3: empty retrieval results must be attributable either to
    // "window not materialized" or "no data in the window".
    let (fs, w) = open();
    materialize_daily(&fs, &w, 4);

    // A never-materialized future window: gap reported.
    let future = FeatureWindow::new(10 * DAY, 11 * DAY);
    assert!(!fs.is_materialized(&w.txn_table, future));
    assert_eq!(fs.scheduler.gaps(&w.txn_table, future), vec![future]);

    // A materialized window with a ghost entity: materialized, no rows —
    // i.e. genuinely no data.
    let past = FeatureWindow::new(DAY, 2 * DAY);
    assert!(fs.is_materialized(&w.txn_table, past));
    let ghost = fs.get_online(&w.principal, &w.txn_table, "ghost_customer", "eastus").unwrap();
    assert!(ghost.record.is_none());
}
