//! Experiment E7: context-aware scheduling invariants (§3.1.1, §4.3)
//! under concurrency — no overlapping claims, suspension/resume, exact
//! coverage accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use geofs::exec::{RetryPolicy, ThreadPool};
use geofs::scheduler::{SchedulePolicy, Scheduler};
use geofs::types::time::{Granularity, DAY, HOUR};
use geofs::types::FeatureWindow;
use geofs::util::Clock;

fn policy(max_bins: i64) -> SchedulePolicy {
    SchedulePolicy {
        granularity: Granularity(HOUR),
        interval_secs: DAY,
        source_delay_secs: 0,
        max_bins_per_job: max_bins,
    }
}

#[test]
fn concurrent_jobs_never_overlap_windows() {
    // Jobs record the window they're executing; an overlap monitor
    // asserts pairwise disjointness of everything in flight.
    let sched = Scheduler::new(Arc::new(ThreadPool::new(8)), Clock::fixed(0), RetryPolicy::none());
    let in_flight: Arc<Mutex<Vec<FeatureWindow>>> = Default::default();
    let overlaps = Arc::new(AtomicU64::new(0));

    sched.clock.set(10 * DAY);
    let inf = in_flight.clone();
    let ovl = overlaps.clone();
    let out = sched.tick(
        "t",
        &policy(6), // 4 jobs per day × 10 days = 40 concurrent-ish jobs
        0,
        Arc::new(move |w, _| {
            {
                let mut g = inf.lock().unwrap();
                if g.iter().any(|other| other.overlaps(&w)) {
                    ovl.fetch_add(1, Ordering::SeqCst);
                }
                g.push(w);
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
            inf.lock().unwrap().retain(|x| x != &w);
            Ok(1)
        }),
    );
    assert_eq!(out.len(), 40);
    assert_eq!(overlaps.load(Ordering::SeqCst), 0, "overlapping windows observed");
    assert!(sched.is_materialized("t", &FeatureWindow::new(0, 10 * DAY)));
}

#[test]
fn concurrent_backfills_partition_cleanly() {
    // Two overlapping backfill requests race: each window is executed at
    // most once per claim, already-covered pieces are skipped, and the
    // union is exactly covered.
    let sched = Arc::new(Scheduler::new(
        Arc::new(ThreadPool::new(8)),
        Clock::fixed(0),
        RetryPolicy::none(),
    ));
    let executed: Arc<Mutex<Vec<FeatureWindow>>> = Default::default();
    let p = policy(24);
    std::thread::scope(|s| {
        for range in [(0, 6 * DAY), (3 * DAY, 9 * DAY)] {
            let sched = sched.clone();
            let executed = executed.clone();
            let p = p.clone();
            s.spawn(move || {
                let exec2 = executed.clone();
                sched.backfill(
                    "t",
                    &p,
                    FeatureWindow::new(range.0, range.1),
                    Arc::new(move |w, _| {
                        exec2.lock().unwrap().push(w);
                        Ok(1)
                    }),
                );
            });
        }
    });
    // Coverage is the union.
    assert!(sched.is_materialized("t", &FeatureWindow::new(0, 9 * DAY)));
    // The overlapped region may be executed once or twice (claims are
    // serialized, recompute of a completed window is allowed), but never
    // concurrently — and the per-execution windows must tile each request.
    let execs = executed.lock().unwrap();
    assert!(execs.len() >= 9 && execs.len() <= 12, "executions: {}", execs.len());
}

#[test]
fn failed_windows_leave_no_coverage_and_retry_later() {
    let sched = Scheduler::new(Arc::new(ThreadPool::new(4)), Clock::fixed(0), RetryPolicy::none());
    sched.clock.set(2 * DAY);
    let fail_first = Arc::new(AtomicU64::new(0));
    let ff = fail_first.clone();
    let out = sched.tick(
        "t",
        &policy(24),
        0,
        Arc::new(move |w, _| {
            if w.start == 0 && ff.fetch_add(1, Ordering::SeqCst) == 0 {
                Err(geofs::types::FsError::InjectedFault("boom".into()))
            } else {
                Ok(1)
            }
        }),
    );
    assert_eq!(out.len(), 1); // day 2 succeeded, day 1 failed
    assert_eq!(sched.alerts.count_at_least(geofs::scheduler::Severity::Critical), 1);
    assert_eq!(sched.gaps("t", FeatureWindow::new(0, 2 * DAY)), vec![FeatureWindow::new(0, DAY)]);

    // Next tick retries the gap? Scheduled ticks only extend the high
    // water; the gap is a backfill's job (explicit, like the paper's
    // on-demand backfill).
    let out = sched.backfill("t", &policy(24), FeatureWindow::new(0, DAY), Arc::new(|_, _| Ok(1)));
    assert_eq!(out.len(), 1);
    assert!(sched.is_materialized("t", &FeatureWindow::new(0, 2 * DAY)));
}

#[test]
fn coalescing_reduces_job_count() {
    // §3.1.1 "distribution or coalescing of feature windows": the same
    // span partitioned with a larger job unit runs fewer jobs.
    let runs = |max_bins: i64| -> usize {
        let sched =
            Scheduler::new(Arc::new(ThreadPool::new(4)), Clock::fixed(0), RetryPolicy::none());
        sched.clock.set(4 * DAY);
        sched
            .backfill("t", &policy(max_bins), FeatureWindow::new(0, 4 * DAY), Arc::new(|_, _| Ok(1)))
            .len()
    };
    assert_eq!(runs(6), 16);
    assert_eq!(runs(24), 4);
    assert_eq!(runs(96), 1);
}

#[test]
fn suspension_is_per_table() {
    let sched = Arc::new(Scheduler::new(
        Arc::new(ThreadPool::new(4)),
        Clock::fixed(0),
        RetryPolicy::none(),
    ));
    sched.clock.set(DAY);
    // Backfill table A while ticking table B: B is unaffected.
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let rx = Mutex::new(rx);
    std::thread::scope(|s| {
        let sa = sched.clone();
        let h = s.spawn(move || {
            sa.backfill(
                "a",
                &policy(24),
                FeatureWindow::new(0, DAY),
                Arc::new(move |_, _| {
                    let _ = rx.lock().unwrap().recv_timeout(std::time::Duration::from_secs(5));
                    Ok(1)
                }),
            )
        });
        // While A's backfill is in flight, B ticks normally.
        while !sched.is_suspended("a") {
            std::thread::yield_now();
        }
        let out_b = sched.tick("b", &policy(24), 0, Arc::new(|_, _| Ok(1)));
        assert_eq!(out_b.len(), 1, "table b must not be suspended by a's backfill");
        drop(tx);
        h.join().unwrap();
    });
    assert!(!sched.is_suspended("a"));
}
