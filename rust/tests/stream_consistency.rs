//! Online–offline consistency of the streaming ingestion engine.
//!
//! The headline guarantee (ISSUE 3 acceptance): for any event sequence
//! — out-of-order, duplicated, chunked arbitrarily across polls — the
//! streaming dual-write path and a batch backfill of the same events
//! produce **identical** offline `TrainingFrame`s and **identical**
//! online lookups once the stream is drained. No online–offline skew,
//! no data leakage past the watermark.
//!
//! Plus: a watermark out-of-order/late-event property test and the
//! consumer crash/resume checkpoint test.

use std::sync::Arc;

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::governance::rbac::{Grant, Principal, Role};
use geofs::materialize::Materializer;
use geofs::metadata::assets::{EntitySpec, FeatureSetSpec, SourceSpec};
use geofs::monitor::freshness::FreshnessTracker;
use geofs::monitor::metrics::MetricsRegistry;
use geofs::offline_store::OfflineStore;
use geofs::online_store::OnlineStore;
use geofs::query::pit::PitConfig;
use geofs::query::spec::FeatureRef;
use geofs::source::Event;
use geofs::stream::{
    CheckpointStore, StreamConfig, StreamDeps, StreamEvent, StreamIngestor,
};
use geofs::testkit::FixedSource;
use geofs::types::time::{Granularity, HOUR};
use geofs::types::{EntityInterner, FeatureWindow, Timestamp};
use geofs::util::rng::Rng;
use geofs::util::Clock;

// ---------------------------------------------------------------- fixtures

fn open_store() -> Arc<FeatureStore> {
    let fs = FeatureStore::open(
        Config::default_local(),
        OpenOptions { with_engine: false, ..Default::default() },
    )
    .unwrap();
    fs.create_store("fs-stream").unwrap();
    fs.create_entity(EntitySpec::new("customer", 1, &["customer_id"])).unwrap();
    fs.rbac.grant(Grant {
        principal: Principal("alice".into()),
        store: "fs-stream".into(),
        role: Role::Admin,
        workspace: "ws".into(),
        workspace_region: "local".into(),
    });
    fs
}

fn spec(window_bins: usize) -> FeatureSetSpec {
    FeatureSetSpec::rolling(
        "txn",
        1,
        "customer",
        SourceSpec::synthetic(0),
        Granularity(HOUR),
        window_bins,
    )
}

/// Random event sequence: mostly-ordered timeline with bounded jitter,
/// a tail of genuinely late stragglers, and ~10% duplicate deliveries.
fn gen_events(rng: &mut Rng, n: usize, entities: u64, span_hours: i64) -> Vec<StreamEvent> {
    let mut out: Vec<StreamEvent> = Vec::with_capacity(n + n / 8);
    let span = span_hours * HOUR;
    for seq in 0..n as u64 {
        let base = (seq as i64 * span) / n as i64;
        let jitter = rng.range(-2 * HOUR, 2 * HOUR);
        let ts = (base + jitter).clamp(0, span - 1);
        let key = format!("cust_{:03}", rng.below(entities));
        out.push(StreamEvent::new(seq, key, ts, (rng.f32() * 10.0).round()));
    }
    // Stragglers: old event times delivered at the very end (→ late
    // relative to any bounded watermark).
    for k in 0..(n / 20).max(1) {
        let seq = (n + k) as u64;
        let key = format!("cust_{:03}", rng.below(entities));
        out.push(StreamEvent::new(seq, key, rng.range(0, span / 4), 1.0));
    }
    // Duplicate deliveries of random already-sent events.
    for _ in 0..n / 10 {
        let dup = out[rng.below(out.len() as u64) as usize].clone();
        out.push(dup);
    }
    out
}

/// Unique events (first delivery per seq) as the batch source's truth.
fn unique_events(events: &[StreamEvent]) -> Vec<Event> {
    let mut seen = std::collections::HashSet::new();
    events
        .iter()
        .filter(|e| seen.insert(e.seq))
        .map(|e| Event { key: e.key.clone(), ts: e.ts, value: e.value })
        .collect()
}

/// Online state keyed by entity string (entity ids are interner-local,
/// so cross-store comparison must go through resolved keys).
fn online_by_key(fs: &FeatureStore, table: &str, now: Timestamp) -> Vec<(String, Timestamp, Vec<f32>)> {
    let mut out: Vec<(String, Timestamp, Vec<f32>)> = fs
        .online
        .dump_table(table, now)
        .into_iter()
        .map(|r| (fs.interner.resolve(r.entity).unwrap(), r.event_ts, r.values.to_vec()))
        .collect();
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

// ------------------------------------------------- differential guarantee

/// The core oracle: stream `events` through one store, batch-backfill
/// the same (deduped) events through another, assert identical
/// TrainingFrames and identical online lookups after drain.
fn assert_stream_equals_backfill(seed: u64, n: usize, entities: u64, span_hours: i64, lateness: i64) {
    let mut rng = Rng::new(seed);
    let events = gen_events(&mut rng, n, entities, span_hours);
    let uniques = unique_events(&events);
    let t_end = (span_hours + 2) * HOUR;

    // --- streaming path: chunked ingestion, clock advancing per chunk.
    let fs_stream = open_store();
    let table = fs_stream
        .register_feature_set(spec(3), Arc::new(FixedSource(Vec::new())), 0)
        .unwrap();
    fs_stream
        .start_stream(
            &table,
            StreamConfig { partitions: 3, allowed_lateness_secs: lateness, ..Default::default() },
        )
        .unwrap();
    let chunks = 5;
    for (i, chunk) in events.chunks(events.len().div_ceil(chunks)).enumerate() {
        fs_stream.clock.set(span_hours * HOUR + i as i64 * 60);
        fs_stream.stream_ingest(&table, chunk).unwrap();
        fs_stream.poll_stream(&table).unwrap();
    }
    fs_stream.clock.set(t_end);
    // Punctuation: one event per entity, far enough out that every
    // partition's watermark passes the end of the backfill window —
    // after drain the stream has finalized exactly the region the batch
    // path materializes. The punctuation bin itself stays past the
    // watermark forever, so it never materializes on the stream side
    // (and the batch side never reads it — it is outside the backfill
    // window).
    let max_seq = events.iter().map(|e| e.seq).max().unwrap();
    let punct_ts = (span_hours + 1) * HOUR + lateness;
    let punctuation: Vec<StreamEvent> = (0..entities)
        .map(|e| StreamEvent::new(max_seq + 1 + e, format!("cust_{e:03}"), punct_ts, 0.0))
        .collect();
    fs_stream.stream_ingest(&table, &punctuation).unwrap();
    fs_stream.drain_stream(&table).unwrap();
    assert_eq!(fs_stream.stream_watermark(&table), Some(punct_ts - lateness));

    // --- batch path: one backfill over the whole window at t_end.
    let fs_batch = open_store();
    let table_b = fs_batch
        .register_feature_set(spec(3), Arc::new(FixedSource(uniques)), 0)
        .unwrap();
    assert_eq!(table, table_b);
    fs_batch.clock.set(t_end);
    fs_batch.backfill(&table_b, FeatureWindow::new(0, (span_hours + 1) * HOUR)).unwrap();

    // --- online state must agree exactly: same entities, same Eq. 2
    // winner per entity, same values (creation_ts differs by design —
    // it records *when* each path materialized).
    let now = t_end + 1;
    let stream_online = online_by_key(&fs_stream, &table, now);
    let batch_online = online_by_key(&fs_batch, &table, now);
    assert_eq!(stream_online, batch_online, "online state diverges (seed {seed})");
    assert!(!stream_online.is_empty());

    // --- offline: identical TrainingFrames (same observations, cells
    // compared; obs after both paths' creation times).
    let alice = Principal("alice".into());
    let features: Vec<FeatureRef> = ["3h_sum", "3h_cnt", "3h_max"]
        .iter()
        .map(|f| FeatureRef::parse(&format!("txn:1:{f}")).unwrap())
        .collect();
    let mut obs_rng = Rng::new(seed ^ 0xdead);
    let mut observations: Vec<(String, Timestamp)> = (0..120)
        .map(|_| {
            (
                format!("cust_{:03}", obs_rng.below(entities + 2)), // incl. unknown keys
                t_end + obs_rng.range(0, 10 * HOUR),
            )
        })
        .collect();
    observations.push(("cust_000".into(), t_end));
    for cfg in [
        PitConfig::default(),
        PitConfig { availability_slack: 0, max_staleness: 12 * HOUR },
    ] {
        let frame_s = fs_stream
            .get_training_frame(&alice, None, &observations, &features, cfg, "local")
            .unwrap();
        let frame_b = fs_batch
            .get_training_frame(&alice, None, &observations, &features, cfg, "local")
            .unwrap();
        assert_eq!(frame_s.columns, frame_b.columns);
        assert_eq!(frame_s.data, frame_b.data, "training cells diverge (seed {seed}, cfg {cfg:?})");
        assert!(frame_s.fill_rate() > 0.0, "degenerate case: nothing resolved (seed {seed})");
    }
}

#[test]
fn streamed_equals_backfill_ordered() {
    // lateness bound generous → no late events at all.
    assert_stream_equals_backfill(1, 300, 8, 24, 4 * HOUR);
}

#[test]
fn streamed_equals_backfill_tight_watermark() {
    // lateness 0 → every out-of-order event and all stragglers take the
    // late-repair path.
    assert_stream_equals_backfill(2, 300, 8, 24, 0);
}

#[test]
fn streamed_equals_backfill_property() {
    // Randomized sweep over shapes and bounds.
    for seed in 10..16 {
        let mut rng = Rng::new(seed * 977);
        let n = 80 + rng.below(240) as usize;
        let entities = 3 + rng.below(10);
        let span = 12 + rng.range(0, 24);
        let lateness = [0, HOUR / 2, HOUR, 3 * HOUR][rng.below(4) as usize];
        assert_stream_equals_backfill(seed, n, entities, span, lateness);
    }
}

// ----------------------------------------------------------- crash/resume

fn standalone_deps(clock: Clock) -> StreamDeps {
    StreamDeps {
        materializer: Arc::new(Materializer::new(None, Arc::new(EntityInterner::new()))),
        offline: Arc::new(OfflineStore::new()),
        online: Arc::new(OnlineStore::new(4)),
        freshness: Arc::new(FreshnessTracker::new()),
        metrics: Arc::new(MetricsRegistry::new()),
        clock,
        pool: None,
        fabric: None,
        checkpoints: None,
        tracer: None,
    }
}

#[test]
fn crash_resume_from_checkpoint_is_exactly_once() {
    use geofs::query::offline::naive_training_frame;
    use geofs::testkit::TempDir;
    let mut rng = Rng::new(77);
    let events = gen_events(&mut rng, 240, 6, 24);
    let cfg = StreamConfig { partitions: 3, allowed_lateness_secs: HOUR, ..Default::default() };

    // Reference: one engine, no crash, processes everything in one run.
    let ref_clock = Clock::fixed(40 * HOUR);
    let ref_deps = standalone_deps(ref_clock.clone());
    let (ref_offline, ref_online) = (ref_deps.offline.clone(), ref_deps.online.clone());
    let reference = StreamIngestor::new(spec(3), cfg.clone(), ref_deps).unwrap();
    reference.ingest(&events).unwrap();
    ref_clock.set(44 * HOUR);
    reference.drain().unwrap();

    // Crashing run: same durable substrate (stores + log) across two
    // engine incarnations; checkpoint persisted to disk between them.
    let clock = Clock::fixed(40 * HOUR);
    let deps = standalone_deps(clock.clone());
    let (offline, online) = (deps.offline.clone(), deps.online.clone());
    let engine1 = StreamIngestor::with_log(
        spec(3),
        cfg.clone(),
        deps,
        Arc::new(geofs::stream::EventLog::new(3)),
    )
    .unwrap();
    let log = engine1.log().clone();

    let (half, rest) = events.split_at(events.len() / 2);
    engine1.ingest(half).unwrap();
    engine1.poll().unwrap();
    // Commit a checkpoint (flush barrier), then do MORE uncommitted work
    // before the crash — that work must be replayed on resume, neither
    // lost nor double-applied.
    let ckpt = CheckpointStore::new();
    engine1.checkpoint_to(&ckpt);
    let committed_total: u64 =
        (0..3).map(|p| ckpt.get("default", reference.table(), p).unwrap().offset).sum();
    let dir = TempDir::new("stream-ckpt");
    let path = dir.file("offsets.json");
    ckpt.persist(&path).unwrap();
    let (uncommitted, after_crash) = rest.split_at(rest.len() / 2);
    engine1.ingest(uncommitted).unwrap();
    clock.set(41 * HOUR);
    engine1.poll().unwrap();
    drop(engine1); // crash: in-memory pipeline state gone; log + sinks survive

    // Resume: a fresh engine incarnation over the same log + sinks,
    // restored from the on-disk checkpoint. The restart happens later on
    // the processing timeline, as restarts do.
    clock.set(42 * HOUR);
    let deps2 = StreamDeps {
        materializer: Arc::new(Materializer::new(None, Arc::new(EntityInterner::new()))),
        offline: offline.clone(),
        online: online.clone(),
        freshness: Arc::new(FreshnessTracker::new()),
        metrics: Arc::new(MetricsRegistry::new()),
        clock: clock.clone(),
        pool: None,
        fabric: None,
        checkpoints: None,
        tracer: None,
    };
    let engine2 = StreamIngestor::with_log(spec(3), cfg, deps2, log.clone()).unwrap();
    engine2.restore_from(&CheckpointStore::load(&path).unwrap()).unwrap();
    // The checkpoint really skips committed work: consumers resume at
    // the committed offsets, not 0.
    assert!(committed_total > 0, "first half must have committed something");
    engine2.ingest(after_crash).unwrap();
    clock.set(44 * HOUR);
    engine2.drain().unwrap();

    // Served state ≡ the no-crash reference. (Raw offline row sets may
    // differ in creation_ts bookkeeping — replays append benign extra
    // versions — but everything either path *serves* must be identical.)
    let table = reference.table().to_string();
    let ref_interner = reference.interner();
    let got_interner = engine2.interner();
    let norm_online = |store: &OnlineStore, interner: &EntityInterner| {
        let mut v: Vec<(String, Timestamp, Vec<f32>)> = store
            .dump_table(&table, i64::MAX - 1)
            .into_iter()
            .map(|r| (interner.resolve(r.entity).unwrap(), r.event_ts, r.values.to_vec()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    };
    assert_eq!(
        norm_online(&online, &got_interner),
        norm_online(&ref_online, &ref_interner),
        "online state must match the no-crash run"
    );

    // PIT-visible history matches: same training cells from both runs
    // for observations after everything materialized.
    let mut specs = std::collections::HashMap::new();
    specs.insert("txn".to_string(), spec(3));
    let features: Vec<FeatureRef> = ["3h_sum", "3h_cnt"]
        .iter()
        .map(|f| FeatureRef::parse(&format!("txn:1:{f}")).unwrap())
        .collect();
    let keys: Vec<String> = (0..6).map(|e| format!("cust_{e:03}")).collect();
    let mut compared = 0;
    for key in &keys {
        let (Some(e_ref), Some(e_got)) = (ref_interner.lookup(key), got_interner.lookup(key))
        else {
            continue; // key never materialized (all its events past the watermark)
        };
        compared += 1;
        for ts in [45 * HOUR, 50 * HOUR, 60 * HOUR] {
            let obs_ref = geofs::query::pit::Observation { entity: e_ref, ts };
            let obs_got = geofs::query::pit::Observation { entity: e_got, ts };
            let frame_ref =
                naive_training_frame(&ref_offline, &[obs_ref], &features, &specs, PitConfig::default())
                    .unwrap();
            let frame_got =
                naive_training_frame(&offline, &[obs_got], &features, &specs, PitConfig::default())
                    .unwrap();
            assert_eq!(frame_ref.data, frame_got.data, "PIT cells diverge for {key} at {ts}");
        }
    }
    assert!(compared >= 3, "too few entities materialized to be meaningful: {compared}");
}

// ----------------------------------------------- watermark property (e2e)

#[test]
fn watermark_never_leaks_unfinalized_data() {
    // Data leakage guard: at every poll, no offline record's event_ts
    // may exceed the table watermark (records only exist for finalized
    // bins), and every record's creation_ts is ≥ the moment its bin was
    // finalized — training can never see values inference couldn't have.
    // One partition so the table watermark IS the partition watermark —
    // the leakage bound below is then exact, not a cross-partition min.
    let clock = Clock::fixed(100 * HOUR);
    let deps = standalone_deps(clock.clone());
    let offline = deps.offline.clone();
    let ing = StreamIngestor::new(
        spec(2),
        StreamConfig { partitions: 1, allowed_lateness_secs: HOUR, ..Default::default() },
        deps,
    )
    .unwrap();
    let table = ing.table().to_string();
    let mut rng = Rng::new(5);
    let events = gen_events(&mut rng, 200, 5, 30);
    let mut late_seen = 0;
    for chunk in events.chunks(17) {
        ing.ingest(chunk).unwrap();
        let stats = ing.poll().unwrap();
        late_seen = stats.pipeline.late;
        if let Some(wm) = stats.watermark {
            let rows = offline.scan(&table, FeatureWindow::new(i64::MIN / 2, i64::MAX / 2));
            for r in &rows {
                assert!(
                    r.event_ts <= wm,
                    "record at event {} leaked past watermark {wm}",
                    r.event_ts
                );
            }
        }
    }
    ing.drain().unwrap();
    assert!(late_seen > 0, "the straggler tail must exercise the late path");
    // Watermark monotone across the run and consistent with stats.
    let final_wm = ing.watermark().unwrap();
    assert!(final_wm >= 30 * HOUR - 3 * HOUR, "final watermark implausibly low: {final_wm}");
}
