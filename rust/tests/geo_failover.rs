//! Experiment E11 (§3.1.2): region outage → standby restore → resume
//! without data loss; plus cross-region behavior during the outage.

use std::sync::Arc;

use geofs::config::Config;
use geofs::coordinator::{DurabilityOptions, FeatureStore, OpenOptions};
use geofs::exec::{RetryPolicy, ThreadPool};
use geofs::geo::failover::FailoverManager;
use geofs::scheduler::Scheduler;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::testkit::TempDir;
use geofs::types::time::DAY;
use geofs::types::{FeatureWindow, FsError};
use geofs::util::Clock;

#[test]
fn full_failover_no_loss_no_rework() {
    let dir = TempDir::new("it-fo-full");
    // Primary runs 5 days.
    let fs = FeatureStore::open(Config::default_geo(), OpenOptions::default()).unwrap();
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 24, days: 5, seed: 1, ..Default::default() },
    )
    .unwrap();
    for day in 1..=5 {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table).unwrap();
    }
    let rows = fs.offline.row_count(&w.txn_table);
    let latest_before = fs.offline.latest_per_entity(&w.txn_table);
    let cp = fs.checkpoint(dir.path().to_path_buf()).unwrap();

    // Outage.
    fs.topology.set_down("eastus", true);

    // During the outage, cross-region reads against the home fail loudly
    // (route surfaces RegionDown, not a silent miss).
    let err = fs.get_online(&w.principal, &w.txn_table, "cust_00000", "westus");
    assert!(matches!(err, Err(FsError::RegionDown(_))), "got {err:?}");

    // Standby restores.
    let standby_sched = Scheduler::new(
        Arc::new(ThreadPool::new(2)),
        Clock::fixed(6 * DAY),
        RetryPolicy::default(),
    );
    let fm = FailoverManager::new(fs.topology.clone());
    let promoted = fm.failover(&cp, &standby_sched, 8, 6 * DAY).unwrap();
    let (offline2, online2) = (promoted.offline.clone(), promoted.online.clone());
    assert_eq!(promoted.region, "westus");
    assert_eq!(offline2.row_count(&w.txn_table), rows, "offline data loss");
    // Online rebuilt to the exact Eq. 2 state.
    for rec in &latest_before {
        let got = online2.get(&w.txn_table, rec.entity, 7 * DAY).unwrap();
        assert_eq!(got.version(), rec.version());
        assert_eq!(got.values, rec.values);
    }
    // Scheduler resumes exactly at the high-water mark.
    assert!(standby_sched.is_materialized(&w.txn_table, &FeatureWindow::new(0, 5 * DAY)));
    assert_eq!(
        standby_sched.gaps(&w.txn_table, FeatureWindow::new(0, 6 * DAY)),
        vec![FeatureWindow::new(5 * DAY, 6 * DAY)]
    );
}

#[test]
fn replica_survives_home_outage() {
    // With geo-replication enabled, consumers in replica regions keep
    // reading (stale-but-available) while the home is down — the HA
    // rationale for the replication mechanism.
    let fs = FeatureStore::open(
        Config::default_geo(),
        OpenOptions { geo_replication: true, ..Default::default() },
    )
    .unwrap();
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 12, days: 3, seed: 2, ..Default::default() },
    )
    .unwrap();
    for day in 1..=3 {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table).unwrap();
    }
    fs.clock.advance(600);
    fs.pump_replication();

    fs.topology.set_down("eastus", true);
    let out = fs.get_online(&w.principal, &w.txn_table, "cust_00001", "westeurope").unwrap();
    assert!(out.record.is_some(), "replica must keep serving during home outage");
    assert_eq!(out.mechanism, geofs::geo::access::AccessMechanism::Replica);
    // A region with no replica still fails loudly... unless it also has
    // one (we replicate to all non-home regions), so take the home region
    // consumer itself: its local store IS the down region.
    let err = fs.get_online(&w.principal, &w.txn_table, "cust_00001", "eastus");
    assert!(err.is_err() || err.unwrap().record.is_some());
}

/// ISSUE 9: restarting the *same* region needs no [`RegionCheckpoint`]
/// — a store opened with durability recovers purely from its newest
/// manifest plus WAL tail replay, and converges with the surviving
/// replicas on every acked write, including writes that post-date the
/// last durable checkpoint and never replicated anywhere.
#[test]
fn durable_restart_recovers_from_manifest_and_tail() {
    let dir = TempDir::new("it-fo-durable");
    let open = || {
        FeatureStore::open(
            Config::default_geo(),
            OpenOptions {
                with_engine: false,
                geo_replication: true,
                durability: Some(DurabilityOptions::at(dir.path())),
                ..Default::default()
            },
        )
        .unwrap()
    };
    let wcfg = ChurnWorkloadConfig { customers: 12, days: 4, seed: 7, ..Default::default() };

    let fs = open();
    let w = ChurnWorkload::install(&fs, wcfg.clone()).unwrap();
    for day in 1..=3 {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table).unwrap();
    }
    fs.clock.advance(600);
    fs.pump_replication(); // replicas apply days 1..=3
    fs.checkpoint_durable().unwrap();
    let rows_ckpt = fs.offline.row_count(&w.txn_table);

    // Post-checkpoint acked writes: day 4 reaches the WAL but no new
    // checkpoint is taken and no replica applies it before the "crash".
    fs.clock.set(4 * DAY);
    fs.materialize_tick(&w.txn_table).unwrap();
    let rows_full = fs.offline.row_count(&w.txn_table);
    assert!(rows_full > rows_ckpt, "day 4 must add post-checkpoint rows");
    let probe_keys = ["cust_00000", "cust_00003", "cust_00007"];
    let expect: Vec<_> = probe_keys
        .iter()
        .map(|k| {
            let r = fs
                .get_online(&w.principal, &w.txn_table, k, "eastus")
                .unwrap()
                .record
                .expect("home serves pre-crash state");
            (r.version(), r.values.clone())
        })
        .collect();
    drop(fs); // process crash: nothing persisted beyond WAL + manifest

    // Restart the same region purely from manifest + WAL tail replay —
    // no RegionCheckpoint, no full segment dump.
    let fs2 = open();
    let w2 = ChurnWorkload::install(&fs2, wcfg).unwrap();
    assert_eq!(w2.txn_table, w.txn_table);
    // Scheduler coverage restored from the manifest: days 1..=3 are
    // never re-materialized; the post-checkpoint day 4 is the only gap.
    assert!(fs2.is_materialized(&w.txn_table, FeatureWindow::new(0, 3 * DAY)));
    assert_eq!(
        fs2.scheduler.gaps(&w.txn_table, FeatureWindow::new(0, 4 * DAY)),
        vec![FeatureWindow::new(3 * DAY, 4 * DAY)]
    );
    // Offline restored from the checkpointed segment set alone.
    assert_eq!(fs2.offline.row_count(&w.txn_table), rows_ckpt);

    // Replicas converge without re-materializing anything: history
    // below the recovered cursors flows from the restored offline store
    // via bootstrap, and the day-4 acked writes replay from the WAL
    // tail above the recovered cursors.
    fs2.clock.set(4 * DAY + 600);
    fs2.pump_replication(); // recovered tail passes the lag bound
    fs2.bootstrap_online_from_offline(&w.txn_table).unwrap();
    fs2.clock.advance(600);
    fs2.pump_replication(); // bootstrap batches pass the lag bound
    for (k, (version, values)) in probe_keys.iter().zip(&expect) {
        let r = fs2
            .get_online(&w2.principal, &w.txn_table, k, "westeurope")
            .unwrap()
            .record
            .unwrap_or_else(|| panic!("replica must serve recovered state for {k}"));
        assert_eq!(r.version(), *version, "replica did not converge for {k}");
        assert_eq!(r.values, *values, "replica values diverged for {k}");
    }

    // Offline converges by re-running only the post-checkpoint gap
    // (idempotent into the fabric; the replicas absorb the duplicates).
    fs2.materialize_tick(&w.txn_table).unwrap();
    assert_eq!(fs2.offline.row_count(&w.txn_table), rows_full, "offline did not converge");
}

#[test]
fn checkpoint_is_cheap_and_idempotent() {
    let dir = TempDir::new("it-fo-idem");
    let fs = FeatureStore::open(
        Config::default_geo(),
        OpenOptions { with_engine: false, ..Default::default() },
    )
    .unwrap();
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 8, days: 2, seed: 3, ..Default::default() },
    )
    .unwrap();
    for day in 1..=2 {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table).unwrap();
    }
    let cp1 = fs.checkpoint(dir.path().to_path_buf()).unwrap();
    let cp2 = fs.checkpoint(dir.path().to_path_buf()).unwrap();
    assert_eq!(cp1.coverage, cp2.coverage);
    // Restoring from either gives the same offline rows.
    let off1 = geofs::offline_store::OfflineStore::load(&cp1.offline_dir).unwrap();
    assert_eq!(off1.row_count(&w.txn_table), fs.offline.row_count(&w.txn_table));
}
