//! Soak test (load-harness PR): a mixed read/ingest/PIT workload runs
//! against a fully-wired geo store — background compaction and
//! replication drivers live, streaming engine feeding the hourly table,
//! admission gate in front of reads — and the final streamed state must
//! equal a sequential single-threaded oracle fed the identical events.
//!
//! What this pins down:
//! * **Convergence** — concurrent ingestion (3 producers over disjoint
//!   event slices, arbitrary interleave) converges to the same per-key
//!   online state as in-order ingestion, because the pipeline's
//!   watermark + repair machinery is order-independent under unbounded
//!   retention. Values compare within an f32 tolerance: bin sums fold
//!   in arrival order, so the last ulp may legitimately differ.
//! * **Watermark invariant** — after the final drain no online record
//!   of the streamed table carries an event time above the table
//!   watermark, and the dual-write queue is empty.
//! * **Liveness under admission** — readers tolerate typed `Overloaded`
//!   sheds but must observe real served traffic; nothing panics and no
//!   non-overload error escapes any worker.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::thread;
use std::time::Duration;

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::query::pit::PitConfig;
use geofs::serving::AdmissionConfig;
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::stream::{StreamConfig, StreamEvent};
use geofs::types::time::{DAY, HOUR};
use geofs::types::{FsError, Timestamp};
use geofs::util::rng::Rng;

const CUSTOMERS: usize = 32;
const DAYS: i64 = 3;
const BASE_EVENTS: usize = 1_200;

fn dataset() -> ChurnWorkloadConfig {
    ChurnWorkloadConfig { customers: CUSTOMERS, days: DAYS, ..Default::default() }
}

fn stream_cfg() -> StreamConfig {
    // Unbounded backlog: the oracle comparison needs every event in.
    StreamConfig { partitions: 4, ..Default::default() }
}

/// Deterministic event trace: uniform keys, strictly increasing event
/// time, followed by one high-timestamp "flush" event per customer so
/// the watermark passes every base bin on all partitions.
fn events() -> (Vec<StreamEvent>, Vec<StreamEvent>, Timestamp) {
    let start = DAYS * DAY;
    let mut rng = Rng::new(7);
    let base: Vec<StreamEvent> = (0..BASE_EVENTS)
        .map(|i| {
            StreamEvent::new(
                i as u64,
                format!("cust_{:05}", rng.below(CUSTOMERS as u64)),
                start + i as i64 * 2,
                rng.f32(),
            )
        })
        .collect();
    let flush_ts = start + BASE_EVENTS as i64 * 2 + HOUR;
    let flush: Vec<StreamEvent> = (0..CUSTOMERS)
        .map(|c| {
            StreamEvent::new(BASE_EVENTS as u64 + c as u64, format!("cust_{c:05}"), flush_ts, 0.5)
        })
        .collect();
    (base, flush, flush_ts)
}

#[test]
fn mixed_soak_converges_to_sequential_oracle() {
    let (base, flush, flush_ts) = events();

    // --- System under test: geo store, real drivers, admission gate.
    let fs = FeatureStore::open(
        Config::default_geo(),
        OpenOptions {
            with_engine: false,
            geo_replication: true,
            admission: Some(AdmissionConfig {
                tenant_rate: 2_000.0,
                tenant_burst: 1_500.0,
                ..Default::default()
            }),
            ..Default::default()
        },
    )
    .unwrap();
    let w = ChurnWorkload::install(&fs, dataset()).unwrap();
    fs.clock.set(DAYS * DAY);
    fs.materialize_tick(&w.txn_table).unwrap();
    fs.start_stream(&w.interactions_table, stream_cfg()).unwrap();
    let home = fs.config.home_region().to_string();
    let spine: Vec<(String, Timestamp)> = w
        .observation_spine(64)
        .into_iter()
        .map(|(k, ts, _)| (k, ts))
        .collect();
    let features = w.model_features();

    let stop = AtomicBool::new(false);
    let served_reads = AtomicU64::new(0);
    let shed_reads = AtomicU64::new(0);
    thread::scope(|s| {
        // Poller: consumes the stream and moves simulated time so the
        // lag-gated replication driver delivers.
        let poller = s.spawn(|| {
            while !stop.load(Ordering::Acquire) {
                let _ = fs.poll_stream(&w.interactions_table);
                fs.clock.advance(1);
                thread::sleep(Duration::from_millis(1));
            }
        });
        // 3 ingesters over disjoint contiguous slices: worst-case
        // cross-slice reordering for the watermark/repair machinery.
        let mut workers = Vec::new();
        for chunk in base.chunks(base.len().div_ceil(3)) {
            let (fs, w) = (&fs, &w);
            workers.push(s.spawn(move || {
                for ev in chunk {
                    fs.stream_ingest(&w.interactions_table, std::slice::from_ref(ev)).unwrap();
                }
            }));
        }
        // 2 readers: mixed-table batches; Overloaded is the only
        // acceptable failure.
        for r in 0..2u64 {
            let (fs, w) = (&fs, &w);
            let (served, shed, home) = (&served_reads, &shed_reads, home.as_str());
            workers.push(s.spawn(move || {
                let mut rng = Rng::new(100 + r);
                for _ in 0..150 {
                    let keys: Vec<String> = (0..8)
                        .map(|_| format!("cust_{:05}", rng.below(CUSTOMERS as u64)))
                        .collect();
                    let reqs: Vec<(&str, &str)> = keys
                        .iter()
                        .enumerate()
                        .map(|(i, k)| {
                            let t = if i % 2 == 0 { &w.txn_table } else { &w.interactions_table };
                            (t.as_str(), k.as_str())
                        })
                        .collect();
                    match fs.get_online_many_mixed(&w.principal, &reqs, home) {
                        Ok(_) => served.fetch_add(1, Ordering::Relaxed),
                        Err(FsError::Overloaded { .. }) => shed.fetch_add(1, Ordering::Relaxed),
                        Err(e) => panic!("reader hit non-overload error: {e}"),
                    };
                    thread::sleep(Duration::from_micros(200));
                }
            }));
        }
        // 1 PIT thread: offline reads race the stream's dual writes and
        // the background compaction driver.
        {
            let (fs, w) = (&fs, &w);
            let (spine, features, home) = (&spine, &features, home.as_str());
            workers.push(s.spawn(move || {
                let mut rng = Rng::new(9);
                for _ in 0..40 {
                    let obs: Vec<(String, Timestamp)> = (0..4)
                        .map(|_| spine[rng.below(spine.len() as u64) as usize].clone())
                        .collect();
                    fs.get_training_frame(
                        &w.principal,
                        None,
                        &obs,
                        features,
                        PitConfig::default(),
                        home,
                    )
                    .unwrap();
                    thread::sleep(Duration::from_micros(500));
                }
            }));
        }
        for h in workers {
            h.join().unwrap();
        }
        // Producers done: append the flush punctuation, then stop.
        fs.stream_ingest(&w.interactions_table, &flush).unwrap();
        stop.store(true, Ordering::Release);
        poller.join().unwrap();
    });
    let stats = fs.drain_stream(&w.interactions_table).unwrap();
    assert!(served_reads.load(Ordering::Relaxed) > 0, "admission starved all readers");
    assert_eq!(stats.pending_online, 0, "dual-write queue drained");
    let wm = stats.watermark.expect("streamed table has a watermark");
    assert_eq!(wm, flush_ts, "watermark reached the flush punctuation");

    // --- Watermark invariant: nothing served ahead of the watermark.
    let now = flush_ts + 1;
    for rec in fs.online.dump_table(&w.interactions_table, now) {
        assert!(rec.event_ts <= wm, "online record event_ts {} ahead of watermark {wm}", rec.event_ts);
    }

    // --- Oracle: same events, one thread, in order, no concurrency.
    let oracle = FeatureStore::open(
        Config::default_local(),
        OpenOptions { with_engine: false, ..Default::default() },
    )
    .unwrap();
    let ow = ChurnWorkload::install(&oracle, dataset()).unwrap();
    oracle.clock.set(DAYS * DAY);
    oracle.start_stream(&ow.interactions_table, stream_cfg()).unwrap();
    oracle.stream_ingest(&ow.interactions_table, &base).unwrap();
    oracle.stream_ingest(&ow.interactions_table, &flush).unwrap();
    oracle.drain_stream(&ow.interactions_table).unwrap();

    let mut compared = 0;
    for c in 0..CUSTOMERS {
        let key = format!("cust_{c:05}");
        let got = fs
            .interner
            .lookup(&key)
            .and_then(|e| fs.online.get(&w.interactions_table, e, now));
        let want = oracle
            .interner
            .lookup(&key)
            .and_then(|e| oracle.online.get(&ow.interactions_table, e, now));
        match (got, want) {
            (None, None) => {}
            (Some(g), Some(o)) => {
                assert_eq!(g.event_ts, o.event_ts, "key {key}: bin mismatch");
                assert_eq!(g.values.len(), o.values.len(), "key {key}: arity");
                for (i, (gv, ov)) in g.values.iter().zip(o.values.iter()).enumerate() {
                    assert!(
                        (gv - ov).abs() <= 1e-3 + 1e-4 * ov.abs(),
                        "key {key} value[{i}]: {gv} vs oracle {ov}"
                    );
                }
                compared += 1;
            }
            (g, o) => panic!("key {key}: presence diverged (sut {g:?}, oracle {o:?})"),
        }
    }
    assert!(compared > 0, "oracle comparison must cover real state");
}
