//! Differential + stress coverage for the columnar offline store and
//! the streaming PIT merge-join engine (PR 2 tentpole).
//!
//! * `prop_merge_join_matches_naive_oracle` — hundreds of seeded random
//!   cases (records merged in random batch sizes over a tiny spill
//!   threshold, random spines including exact `event_ts` hits and
//!   unknown entities, random availability/staleness configs): the
//!   columnar merge-join — sequential *and* thread-pool fanned — must
//!   equal the retained `naive_training_frame` linear-scan oracle cell
//!   for cell.
//! * `merge_while_query_stress` — concurrent writers (same record set,
//!   shuffled: Alg 2 idempotence under contention), a compaction thread
//!   churning the physical layout, and PIT readers asserting leak
//!   freedom and forward-only winners, mirroring
//!   `tests/online_stress.rs` for the offline path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use geofs::exec::ThreadPool;
use geofs::metadata::assets::{FeatureSetSpec, SourceSpec};
use geofs::offline_store::OfflineStore;
use geofs::query::offline::{naive_training_frame, OfflineQueryEngine};
use geofs::query::pit::{Observation, PitConfig};
use geofs::query::spec::FeatureRef;
use geofs::testkit::prop::{forall, Gen};
use geofs::types::time::Granularity;
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;

fn spec_map() -> HashMap<String, FeatureSetSpec> {
    let mut specs = HashMap::new();
    specs.insert(
        "txn".to_string(),
        FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity::daily(),
            30,
        ),
    );
    specs
}

/// Compact record encoding: (entity, event_ts, creation_delta ≥ 0).
/// Values are a pure function of the uniqueness key so duplicate
/// generation cannot make delivery order observable.
type R = (u64, i64, i64);

fn to_rec(r: &R) -> FeatureRecord {
    let v = (r.0 as i64 * 131 + r.1 * 7 + r.2) as f32;
    FeatureRecord::new(r.0, r.1, r.1 + r.2, vec![v, v + 0.5])
}

fn gen_records(max_len: usize) -> Gen<Vec<R>> {
    Gen::new(move |rng: &mut Rng| {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n)
            .map(|_| (rng.below(6), rng.range(0, 400), rng.range(0, 200)))
            .collect()
    })
}

#[test]
fn prop_merge_join_matches_naive_oracle() {
    let pool = Arc::new(ThreadPool::new(3));
    let specs = spec_map();
    let features = vec![
        FeatureRef::parse("txn:1:720h_sum").unwrap(),
        FeatureRef::parse("txn:1:720h_cnt").unwrap(),
    ];
    forall("merge-join-vs-naive", 150, &gen_records(40), |rs| {
        // Tiny spill threshold: cases exercise multi-segment k-way
        // merges plus the unsealed delta mini-segment.
        let store = Arc::new(OfflineStore::with_spill_threshold(5));
        let recs: Vec<FeatureRecord> = rs.iter().map(to_rec).collect();
        let mut rng = Rng::new(rs.len() as u64 * 1_000_003 + 17);
        let mut i = 0;
        while i < recs.len() {
            let end = (i + 1 + rng.below(7) as usize).min(recs.len());
            store.merge("txn:1", &recs[i..end]);
            i = end;
        }
        if rng.bool(0.3) {
            store.compact("txn:1");
        }
        // Random spine: unknown entities, and ~25% of timestamps landing
        // exactly on an event_ts (the inclusive-end boundary).
        let n_obs = rng.below(30) as usize;
        let mut obs = Vec::with_capacity(n_obs);
        for _ in 0..n_obs {
            let entity = rng.below(8);
            let ts = if !recs.is_empty() && rng.bool(0.25) {
                rng.pick(&recs).event_ts
            } else {
                rng.range(-50, 700)
            };
            obs.push(Observation { entity, ts });
        }
        let cfg = PitConfig {
            availability_slack: if rng.bool(0.5) { 0 } else { rng.range(1, 80) },
            max_staleness: if rng.bool(0.5) { 0 } else { rng.range(1, 500) },
        };
        let seq = OfflineQueryEngine::new(store.clone());
        let fanned = OfflineQueryEngine::with_pool(store.clone(), pool.clone());
        let fast =
            seq.get_training_frame(&obs, &features, &specs, cfg).map_err(|e| e.to_string())?;
        let par =
            fanned.get_training_frame(&obs, &features, &specs, cfg).map_err(|e| e.to_string())?;
        let slow = naive_training_frame(&store, &obs, &features, &specs, cfg)
            .map_err(|e| e.to_string())?;
        if fast != slow {
            return Err(format!(
                "merge-join diverged from oracle (cfg {cfg:?}, shape {:?})",
                store.storage_shape("txn:1")
            ));
        }
        if par != fast {
            return Err("pooled engine diverged from sequential".into());
        }
        Ok(())
    });
}

// ---- merge-while-query stress ------------------------------------------

const STRESS_ENTITIES: u64 = 16;
const EVENTS_PER_ENTITY: i64 = 120;
const EVENT_STEP: i64 = 10;
const DELAY: i64 = 25;

/// Entity `e`'s `k`-th record: event `k * STEP`, materialized `DELAY`
/// later, value column 0 encodes the event timestamp so readers can
/// verify exactly which record won a PIT lookup.
fn stress_rec(entity: u64, k: i64) -> FeatureRecord {
    let event = k * EVENT_STEP;
    FeatureRecord::new(entity, event, event + DELAY, vec![event as f32, entity as f32])
}

#[test]
fn merge_while_query_stress() {
    let store = Arc::new(OfflineStore::with_spill_threshold(64));
    let pool = Arc::new(ThreadPool::new(2));
    let specs = spec_map();
    let features = vec![FeatureRef::parse("txn:1:720h_sum").unwrap()];
    let done = Arc::new(AtomicBool::new(false));

    // Fixed spine: entities including two unknown ones, timestamps
    // spread over (and past) the event range. Large enough that the
    // pooled reader's join splits into several entity chunks.
    let ts_mod = EVENTS_PER_ENTITY * EVENT_STEP + 100;
    let spine: Vec<Observation> = (0..1_200u64)
        .map(|i| Observation {
            entity: i % (STRESS_ENTITIES + 2),
            ts: (i as i64 * 7) % ts_mod,
        })
        .collect();

    std::thread::scope(|s| {
        // Two writers merge the SAME record set in different orders:
        // Alg 2 idempotence under write/write contention.
        let writers: Vec<_> = (0..2u64)
            .map(|w| {
                let store = store.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(0x5eed ^ w);
                    let mut all: Vec<FeatureRecord> = (0..STRESS_ENTITIES)
                        .flat_map(|e| (0..EVENTS_PER_ENTITY).map(move |k| stress_rec(e, k)))
                        .collect();
                    rng.shuffle(&mut all);
                    for chunk in all.chunks(37) {
                        store.merge("txn:1", chunk);
                    }
                })
            })
            .collect();
        // Compactor: churns the physical layout under the readers.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    store.compact("txn:1");
                    std::thread::yield_now();
                }
            });
        }
        // Readers: one sequential engine, one pool-fanned engine. Every
        // returned cell must be leak-free, from the right entity's
        // stream, and per-observation winners must only move forward as
        // records land.
        let mut readers = Vec::new();
        for r in 0..2u64 {
            let store = store.clone();
            let done = done.clone();
            let spine = spine.clone();
            let specs = specs.clone();
            let features = features.clone();
            let pool = pool.clone();
            readers.push(s.spawn(move || {
                let engine = if r == 0 {
                    OfflineQueryEngine::new(store.clone())
                } else {
                    OfflineQueryEngine::with_pool(store.clone(), pool)
                };
                let mut last: Vec<Option<f32>> = vec![None; spine.len()];
                let mut iterations = 0u64;
                loop {
                    let frame = engine
                        .get_training_frame(&spine, &features, &specs, PitConfig::default())
                        .unwrap();
                    for (i, o) in spine.iter().enumerate() {
                        if let Some(v) = frame.value(i, 0) {
                            assert!(o.entity < STRESS_ENTITIES, "unknown entity got a value");
                            let event = v as i64;
                            assert_eq!(event % EVENT_STEP, 0, "value not from a real record");
                            assert!(
                                event + DELAY <= o.ts,
                                "unavailable record served (leak): event {event} at ts {}",
                                o.ts
                            );
                            if let Some(prev) = last[i] {
                                assert!(
                                    v >= prev,
                                    "PIT winner moved backwards at obs {i}: {prev} then {v}"
                                );
                            }
                            last[i] = Some(v);
                        }
                    }
                    iterations += 1;
                    if done.load(Ordering::Relaxed) {
                        break iterations;
                    }
                }
            }));
        }

        for h in writers {
            h.join().unwrap();
        }
        // Give readers a beat of post-write overlap with the compactor.
        std::thread::sleep(std::time::Duration::from_millis(20));
        done.store(true, Ordering::Relaxed);
        for h in readers {
            assert!(h.join().unwrap() > 0, "readers must complete iterations");
        }
    });

    // Converged: no lost or duplicated rows despite double delivery.
    assert_eq!(store.row_count("txn:1"), STRESS_ENTITIES * EVENTS_PER_ENTITY as u64);

    // Final frame equals the naive oracle AND the analytically expected
    // nearest-available record per observation.
    let engine = OfflineQueryEngine::new(store.clone());
    let frame =
        engine.get_training_frame(&spine, &features, &specs, PitConfig::default()).unwrap();
    let oracle =
        naive_training_frame(&store, &spine, &features, &specs, PitConfig::default()).unwrap();
    assert_eq!(frame, oracle);
    let max_event = (EVENTS_PER_ENTITY - 1) * EVENT_STEP;
    for (i, o) in spine.iter().enumerate() {
        let expected = if o.entity >= STRESS_ENTITIES || o.ts < DELAY {
            None
        } else {
            Some((((o.ts - DELAY) / EVENT_STEP) * EVENT_STEP).min(max_event) as f32)
        };
        assert_eq!(frame.value(i, 0), expected, "obs {i} ({o:?})");
    }
}
