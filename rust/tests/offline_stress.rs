//! Differential + stress coverage for the compressed columnar offline
//! store and the streaming PIT merge-join engine (PR 2 tentpole,
//! re-pinned over the PR 4 compression/tiering rebuild).
//!
//! * `prop_merge_join_matches_naive_oracle` — hundreds of seeded random
//!   cases (records merged in random batch sizes over a tiny spill
//!   threshold, random spines including exact `event_ts` hits and
//!   unknown entities, random availability/staleness configs, random
//!   bloom densities including a degraded 1-bit filter, and random
//!   background-compaction ticks churning the tiers): the compressed
//!   merge-join — sequential *and* thread-pool fanned — must equal the
//!   retained `naive_training_frame` linear-scan oracle cell for cell,
//!   and the compressed store's scans must equal an uncompressed
//!   `Vec<FeatureRecord>` oracle row for row.
//! * `prop_idempotence_survives_bloom_false_positives` — Alg 2 dedupe
//!   now rides on per-segment bloom filters + exact probes; with a
//!   deliberately degraded 1-bit-per-key filter (tens of percent false
//!   positives) redeliveries must still dedupe exactly and near-miss
//!   keys must still insert.
//! * `merge_while_query_stress` — concurrent writers (same record set,
//!   shuffled: Alg 2 idempotence under contention), the **real**
//!   background `CompactionDriver` plus an explicit-compact churn thread
//!   racing it (exercising the lost-race abort in `compact_tick`), and
//!   PIT readers asserting leak freedom and forward-only winners,
//!   mirroring `tests/online_stress.rs` for the offline path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use geofs::exec::ThreadPool;
use geofs::metadata::assets::{FeatureSetSpec, SourceSpec};
use geofs::offline_store::{CompactionDriver, OfflineStore, StoreConfig};
use geofs::query::offline::{naive_training_frame, OfflineQueryEngine};
use geofs::query::pit::{Observation, PitConfig};
use geofs::query::spec::FeatureRef;
use geofs::testkit::prop::{forall, Gen};
use geofs::types::time::Granularity;
use geofs::types::{FeatureRecord, FeatureWindow};
use geofs::util::rng::Rng;

fn spec_map() -> HashMap<String, FeatureSetSpec> {
    let mut specs = HashMap::new();
    specs.insert(
        "txn".to_string(),
        FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity::daily(),
            30,
        ),
    );
    specs
}

/// Compact record encoding: (entity, event_ts, creation_delta ≥ 0).
/// Values are a pure function of the uniqueness key so duplicate
/// generation cannot make delivery order observable.
type R = (u64, i64, i64);

fn to_rec(r: &R) -> FeatureRecord {
    let v = (r.0 as i64 * 131 + r.1 * 7 + r.2) as f32;
    FeatureRecord::new(r.0, r.1, r.1 + r.2, vec![v, v + 0.5])
}

fn gen_records(max_len: usize) -> Gen<Vec<R>> {
    Gen::new(move |rng: &mut Rng| {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n)
            .map(|_| (rng.below(6), rng.range(0, 400), rng.range(0, 200)))
            .collect()
    })
}

#[test]
fn prop_merge_join_matches_naive_oracle() {
    let pool = Arc::new(ThreadPool::new(3));
    let specs = spec_map();
    let features = vec![
        FeatureRef::parse("txn:1:720h_sum").unwrap(),
        FeatureRef::parse("txn:1:720h_cnt").unwrap(),
    ];
    forall("merge-join-vs-naive", 150, &gen_records(40), |rs| {
        // Tiny spill threshold: cases exercise multi-segment k-way
        // merges plus the unsealed delta mini-segment. Half the cases
        // run a degraded 1-bit bloom so dedupe leans on the exact probe.
        let mut rng = Rng::new(rs.len() as u64 * 1_000_003 + 17);
        let store = Arc::new(OfflineStore::with_config(StoreConfig {
            spill_rows: 5,
            tier_fanin: 3,
            bloom_bits_per_key: if rng.bool(0.5) { 1 } else { 10 },
        }));
        let recs: Vec<FeatureRecord> = rs.iter().map(to_rec).collect();
        let mut i = 0;
        while i < recs.len() {
            let end = (i + 1 + rng.below(7) as usize).min(recs.len());
            store.merge("txn:1", &recs[i..end]);
            i = end;
            // Random size-tiered background ticks churn the layout the
            // same way the driver thread would.
            if rng.bool(0.15) {
                store.compact_tick();
            }
        }
        if rng.bool(0.3) {
            store.compact("txn:1");
        }
        // Compressed store ≡ uncompressed oracle: every surviving row,
        // bit for bit, through the compressed scan path (duplicates in
        // the generated batch collapse by uniqueness key).
        {
            let mut want: Vec<FeatureRecord> = recs.clone();
            want.sort_by_key(|r| r.unique_key());
            want.dedup_by_key(|r| r.unique_key());
            let mut got = store.scan("txn:1", FeatureWindow::new(i64::MIN / 2, i64::MAX / 2));
            got.sort_by_key(|r| r.unique_key());
            if got != want {
                return Err(format!(
                    "compressed scan diverged from raw oracle ({} vs {} rows, shape {:?})",
                    got.len(),
                    want.len(),
                    store.storage_shape("txn:1")
                ));
            }
            // Time travel agrees with a raw filter too.
            let as_of = rng.range(-10, 650);
            let mut got_asof =
                store.scan_as_of("txn:1", FeatureWindow::new(i64::MIN / 2, i64::MAX / 2), as_of);
            got_asof.sort_by_key(|r| r.unique_key());
            let want_asof: Vec<FeatureRecord> =
                want.iter().filter(|r| r.creation_ts <= as_of).cloned().collect();
            if got_asof != want_asof {
                return Err(format!("as_of {as_of} scan diverged from raw oracle"));
            }
        }
        // Random spine: unknown entities, and ~25% of timestamps landing
        // exactly on an event_ts (the inclusive-end boundary).
        let n_obs = rng.below(30) as usize;
        let mut obs = Vec::with_capacity(n_obs);
        for _ in 0..n_obs {
            let entity = rng.below(8);
            let ts = if !recs.is_empty() && rng.bool(0.25) {
                rng.pick(&recs).event_ts
            } else {
                rng.range(-50, 700)
            };
            obs.push(Observation { entity, ts });
        }
        let cfg = PitConfig {
            availability_slack: if rng.bool(0.5) { 0 } else { rng.range(1, 80) },
            max_staleness: if rng.bool(0.5) { 0 } else { rng.range(1, 500) },
        };
        let seq = OfflineQueryEngine::new(store.clone());
        let fanned = OfflineQueryEngine::with_pool(store.clone(), pool.clone());
        let fast =
            seq.get_training_frame(&obs, &features, &specs, cfg).map_err(|e| e.to_string())?;
        let par =
            fanned.get_training_frame(&obs, &features, &specs, cfg).map_err(|e| e.to_string())?;
        let slow = naive_training_frame(&store, &obs, &features, &specs, cfg)
            .map_err(|e| e.to_string())?;
        if fast != slow {
            return Err(format!(
                "merge-join diverged from oracle (cfg {cfg:?}, shape {:?})",
                store.storage_shape("txn:1")
            ));
        }
        if par != fast {
            return Err("pooled engine diverged from sequential".into());
        }
        Ok(())
    });
}

#[test]
fn prop_idempotence_survives_bloom_false_positives() {
    // 1 bit/key ⇒ the filter answers "maybe" for a large fraction of
    // absent keys; Alg 2 must still be exactly idempotent because every
    // bloom hit is confirmed by a binary-search probe of the segment.
    forall("bloom-fp-idempotence", 120, &gen_records(60), |rs| {
        let store = OfflineStore::with_config(StoreConfig {
            spill_rows: 4,
            tier_fanin: 3,
            bloom_bits_per_key: 1,
        });
        let recs: Vec<FeatureRecord> = rs.iter().map(to_rec).collect();
        let mut rng = Rng::new(rs.len() as u64 * 7_919 + 3);
        // First delivery in random chunks, with churn between chunks.
        let mut i = 0;
        while i < recs.len() {
            let end = (i + 1 + rng.below(5) as usize).min(recs.len());
            store.merge("txn:1", &recs[i..end]);
            if rng.bool(0.2) {
                store.compact_tick();
            }
            i = end;
        }
        let mut unique: Vec<FeatureRecord> = recs.clone();
        unique.sort_by_key(|r| r.unique_key());
        unique.dedup_by_key(|r| r.unique_key());
        if store.row_count("txn:1") != unique.len() as u64 {
            return Err(format!(
                "first delivery: {} rows stored, {} unique keys",
                store.row_count("txn:1"),
                unique.len()
            ));
        }
        // Full redelivery (shuffled): every record must be skipped via
        // the bloom→exact-probe path, none double-inserted.
        let mut replay = recs.clone();
        rng.shuffle(&mut replay);
        let m = store.merge("txn:1", &replay);
        if m.inserted != 0 {
            return Err(format!("redelivery inserted {} rows (bloom FP broke dedupe?)", m.inserted));
        }
        // Near-miss keys (creation_ts shifted past the generator's
        // range) are new versions: false positives must not swallow
        // genuinely-new inserts.
        let shifted: Vec<FeatureRecord> = unique
            .iter()
            .map(|r| FeatureRecord::new(r.entity, r.event_ts, r.creation_ts + 100_000, r.values.to_vec()))
            .collect();
        let m = store.merge("txn:1", &shifted);
        if m.inserted != shifted.len() as u64 {
            return Err(format!(
                "near-miss keys: {} of {} inserted (false positive treated as exact hit)",
                m.inserted,
                shifted.len()
            ));
        }
        if store.row_count("txn:1") != (unique.len() + shifted.len()) as u64 {
            return Err("row count drifted".into());
        }
        Ok(())
    });
}

// ---- merge-while-query stress ------------------------------------------

const STRESS_ENTITIES: u64 = 16;
const EVENTS_PER_ENTITY: i64 = 120;
const EVENT_STEP: i64 = 10;
const DELAY: i64 = 25;

/// Entity `e`'s `k`-th record: event `k * STEP`, materialized `DELAY`
/// later, value column 0 encodes the event timestamp so readers can
/// verify exactly which record won a PIT lookup.
fn stress_rec(entity: u64, k: i64) -> FeatureRecord {
    let event = k * EVENT_STEP;
    FeatureRecord::new(entity, event, event + DELAY, vec![event as f32, entity as f32])
}

#[test]
fn merge_while_query_stress() {
    let store = Arc::new(OfflineStore::with_config(StoreConfig {
        spill_rows: 64,
        tier_fanin: 3,
        ..Default::default()
    }));
    let pool = Arc::new(ThreadPool::new(2));
    let specs = spec_map();
    let features = vec![FeatureRef::parse("txn:1:720h_sum").unwrap()];
    let done = Arc::new(AtomicBool::new(false));
    // The real background driver folds tiers while everything else runs.
    let driver = CompactionDriver::spawn(store.clone(), std::time::Duration::from_millis(1));

    // Fixed spine: entities including two unknown ones, timestamps
    // spread over (and past) the event range. Large enough that the
    // pooled reader's join splits into several entity chunks.
    let ts_mod = EVENTS_PER_ENTITY * EVENT_STEP + 100;
    let spine: Vec<Observation> = (0..1_200u64)
        .map(|i| Observation {
            entity: i % (STRESS_ENTITIES + 2),
            ts: (i as i64 * 7) % ts_mod,
        })
        .collect();

    std::thread::scope(|s| {
        // Two writers merge the SAME record set in different orders:
        // Alg 2 idempotence under write/write contention.
        let writers: Vec<_> = (0..2u64)
            .map(|w| {
                let store = store.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(0x5eed ^ w);
                    let mut all: Vec<FeatureRecord> = (0..STRESS_ENTITIES)
                        .flat_map(|e| (0..EVENTS_PER_ENTITY).map(move |k| stress_rec(e, k)))
                        .collect();
                    rng.shuffle(&mut all);
                    for chunk in all.chunks(37) {
                        store.merge("txn:1", chunk);
                    }
                })
            })
            .collect();
        // Explicit-compact churn racing the background driver: folds
        // everything while the driver picks tiers, exercising the
        // lost-race abort in `compact_tick` on top of the layout churn.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    store.compact("txn:1");
                    std::thread::yield_now();
                }
            });
        }
        // Readers: one sequential engine, one pool-fanned engine. Every
        // returned cell must be leak-free, from the right entity's
        // stream, and per-observation winners must only move forward as
        // records land.
        let mut readers = Vec::new();
        for r in 0..2u64 {
            let store = store.clone();
            let done = done.clone();
            let spine = spine.clone();
            let specs = specs.clone();
            let features = features.clone();
            let pool = pool.clone();
            readers.push(s.spawn(move || {
                let engine = if r == 0 {
                    OfflineQueryEngine::new(store.clone())
                } else {
                    OfflineQueryEngine::with_pool(store.clone(), pool)
                };
                let mut last: Vec<Option<f32>> = vec![None; spine.len()];
                let mut iterations = 0u64;
                loop {
                    let frame = engine
                        .get_training_frame(&spine, &features, &specs, PitConfig::default())
                        .unwrap();
                    for (i, o) in spine.iter().enumerate() {
                        if let Some(v) = frame.value(i, 0) {
                            assert!(o.entity < STRESS_ENTITIES, "unknown entity got a value");
                            let event = v as i64;
                            assert_eq!(event % EVENT_STEP, 0, "value not from a real record");
                            assert!(
                                event + DELAY <= o.ts,
                                "unavailable record served (leak): event {event} at ts {}",
                                o.ts
                            );
                            if let Some(prev) = last[i] {
                                assert!(
                                    v >= prev,
                                    "PIT winner moved backwards at obs {i}: {prev} then {v}"
                                );
                            }
                            last[i] = Some(v);
                        }
                    }
                    iterations += 1;
                    if done.load(Ordering::Relaxed) {
                        break iterations;
                    }
                }
            }));
        }

        for h in writers {
            h.join().unwrap();
        }
        // Give readers a beat of post-write overlap with the compactor.
        std::thread::sleep(std::time::Duration::from_millis(20));
        done.store(true, Ordering::Relaxed);
        for h in readers {
            assert!(h.join().unwrap() > 0, "readers must complete iterations");
        }
    });

    drop(driver);

    // Converged: no lost or duplicated rows despite double delivery.
    assert_eq!(store.row_count("txn:1"), STRESS_ENTITIES * EVENTS_PER_ENTITY as u64);

    // Final frame equals the naive oracle AND the analytically expected
    // nearest-available record per observation.
    let engine = OfflineQueryEngine::new(store.clone());
    let frame =
        engine.get_training_frame(&spine, &features, &specs, PitConfig::default()).unwrap();
    let oracle =
        naive_training_frame(&store, &spine, &features, &specs, PitConfig::default()).unwrap();
    assert_eq!(frame, oracle);
    let max_event = (EVENTS_PER_ENTITY - 1) * EVENT_STEP;
    for (i, o) in spine.iter().enumerate() {
        let expected = if o.entity >= STRESS_ENTITIES || o.ts < DELAY {
            None
        } else {
            Some((((o.ts - DELAY) / EVENT_STEP) * EVENT_STEP).min(max_event) as f32)
        };
        assert_eq!(frame.value(i, 0), expected, "obs {i} ({o:?})");
    }
}
