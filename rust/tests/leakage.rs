//! Experiment E4: data-leakage prevention (§4.4).
//!
//! The integration-level claim: a training frame built by the PIT query
//! engine reproduces exactly what online inference would have seen at
//! each observation time — no future values, no not-yet-materialized
//! values — while a deliberately leaky join (event-time-only) does leak.

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::query::pit::{pit_lookup, Observation, PitConfig, PitIndex};
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::types::time::DAY;
use geofs::types::{FeatureRecord, FeatureWindow};

/// A "leaky" join that ignores creation availability — what a hand-rolled
/// event-time join does, and what the paper warns against.
fn leaky_lookup(records: &[FeatureRecord], obs: Observation) -> Option<FeatureRecord> {
    records
        .iter()
        .filter(|r| r.entity == obs.entity && r.event_ts < obs.ts)
        .max_by_key(|r| (r.event_ts, r.creation_ts))
        .cloned()
}

#[test]
fn pit_join_never_uses_unavailable_records() {
    // Records materialized late: event day d, created at day d+3.
    let records: Vec<FeatureRecord> = (1..=10)
        .map(|d| FeatureRecord::new(7, d * DAY, (d + 3) * DAY, vec![d as f32]))
        .collect();
    let idx = PitIndex::build(records.clone());
    for obs_day in 2..=12 {
        let obs = Observation { entity: 7, ts: obs_day * DAY + 1 };
        let pit = idx.lookup(obs, PitConfig::default()).cloned();
        let leaky = leaky_lookup(&records, obs);
        // The leaky join always returns the newest event (day obs_day-? ) —
        // but that record is only *available* 3 days later.
        if let Some(p) = &pit {
            assert!(p.creation_ts <= obs.ts, "PIT returned unavailable record");
            assert!(p.event_ts < obs.ts);
        }
        let leaked = leaky.as_ref().map(|l| l.creation_ts > obs.ts).unwrap_or(false);
        if leaked {
            assert_ne!(pit, leaky, "obs day {obs_day}: PIT must differ from leaky join");
        }
    }
    // Quantify: just after day 5 the leaky join reads day-5 features
    // (created day 8 — the future!); PIT falls back to day-2 (created
    // day 5, already available).
    let obs = Observation { entity: 7, ts: 5 * DAY + 1 };
    assert_eq!(leaky_lookup(&records, obs).unwrap().values[0], 5.0);
    assert_eq!(idx.lookup(obs, PitConfig::default()).unwrap().values[0], 2.0);
}

#[test]
fn training_matches_serving_no_skew() {
    // Train/serve skew check on the full system: replay time; at each
    // step compare (a) what online serving returns now with (b) what a
    // later PIT training query attributes to this timestamp.
    let fs = FeatureStore::open(Config::default_local(), OpenOptions::default()).unwrap();
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 16, days: 10, seed: 5, ..Default::default() },
    )
    .unwrap();

    let mut served: Vec<(String, i64, Option<f32>)> = Vec::new();
    for day in 1..=10 {
        fs.clock.set(day * DAY);
        fs.materialize_tick(&w.txn_table).unwrap();
        // Online inference for a few customers right after the tick.
        for c in 0..4 {
            let key = format!("cust_{c:05}");
            let out = fs.get_online(&w.principal, &w.txn_table, &key, "local").unwrap();
            served.push((key, fs.clock.now(), out.record.map(|r| r.values[0])));
        }
    }

    // Later (training time), ask the PIT engine what each of those
    // inference calls *should* have seen.
    let observations: Vec<(String, i64)> =
        served.iter().map(|(k, ts, _)| (k.clone(), *ts)).collect();
    let frame = fs
        .get_training_frame(
            &w.principal,
            None,
            &observations,
            &[geofs::query::spec::FeatureRef::parse("txn_30d:1:720h_sum").unwrap()],
            PitConfig::default(),
            "local",
        )
        .unwrap();
    for ((_, _, served_value), row) in served.iter().zip(frame.rows()) {
        assert_eq!(
            row.features[0], *served_value,
            "training value diverged from what serving returned (skew)"
        );
    }
}

#[test]
fn adversarial_future_dated_records_are_invisible() {
    // A buggy upstream writes a record with event_ts in the future.
    // Offline keeps it (Eq. 1), but no PIT query before that time may see
    // it, and the online store (Eq. 2) would serve it only after its
    // event time passes — the query layer guards training.
    let fs = FeatureStore::open(
        Config::default_local(),
        OpenOptions { with_engine: false, ..Default::default() },
    )
    .unwrap();
    fs.create_store("adv").unwrap();
    let future = FeatureRecord::new(1, 100 * DAY, 100 * DAY + 10, vec![666.0]);
    fs.offline.merge("t:1", &[future]);
    let idx = PitIndex::build(fs.offline.scan("t:1", FeatureWindow::new(0, 200 * DAY)));
    for day in 0..100 {
        assert!(
            idx.lookup(Observation { entity: 1, ts: day * DAY }, PitConfig::default()).is_none(),
            "future-dated record leaked at day {day}"
        );
    }
}

#[test]
fn max_staleness_mirrors_online_ttl() {
    // With max_staleness = TTL, the training join refuses features that
    // online would have evicted — removing the silent skew between an
    // unlimited-lookback training join and TTL'd serving.
    let records =
        vec![FeatureRecord::new(1, DAY, DAY + 100, vec![1.0])];
    let obs = Observation { entity: 1, ts: 10 * DAY };
    let unlimited = pit_lookup(&records, obs, PitConfig::default());
    assert!(unlimited.is_some());
    let ttl_matched = pit_lookup(
        &records,
        obs,
        PitConfig { max_staleness: 5 * DAY, ..Default::default() },
    );
    assert!(ttl_matched.is_none());
}
