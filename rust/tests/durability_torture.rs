//! ISSUE 9 acceptance: fault-injected recovery torture tests for the
//! manifest-addressed durable storage layer.
//!
//! The headline differential — **every acked write survives a crash**:
//! an append is acked once its WAL frame is fsynced, and recovery
//! (newest valid manifest + fragment tail replay) must return every
//! acked record at its exact offset, serve no torn or invented record,
//! and leave no orphan file behind after two GC passes. The crash-point
//! sweep drives a seeded workload against a [`FaultFs`] that kills the
//! "process" after N filesystem operations (optionally tearing the
//! in-flight write, as a power cut does), for N sampled across the
//! whole op space.
//!
//! Corruption is tested separately from crashes: truncating a fragment
//! at every byte boundary and flipping single bits in fragments and
//! manifests must either fail closed with a typed
//! [`FsError::Corrupt`], fall back to an older manifest generation, or
//! recover a valid prefix — never serve a damaged record.
//!
//! Environment knobs (all optional; CI drives the matrix with them):
//!
//! * `GEOFS_TORTURE_SEED`   — base seed for the crash schedules.
//! * `GEOFS_TORTURE_POINTS` — crash points per sweep.
//! * `GEOFS_TORTURE_SYNC`   — WAL sync policy for the sweeps
//!   (`per_append` default, `group_commit` for the amortized ack
//!   path); CI runs every seed under both.
//! * `GEOFS_TORTURE_AUDIT`  — directory to write recovered-state audit
//!   JSON documents into (uploaded as a CI artifact).

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::Arc;

use geofs::config::Config;
use geofs::coordinator::{DurabilityOptions, FeatureStore, OpenOptions};
use geofs::metadata::assets::{EntitySpec, FeatureSetSpec, SourceSpec};
use geofs::storage::{DurableLogOptions, DurableStore, RealFs, SyncPolicy, Vfs};
use geofs::stream::{StreamConfig, StreamEvent};
use geofs::testkit::faultfs::{FaultConfig, FaultFs};
use geofs::testkit::{FixedSource, TempDir};
use geofs::types::time::{Granularity, HOUR};
use geofs::types::{FsError, Result};
use geofs::util::backoff::{retry, Backoff};
use geofs::util::json::Json;
use geofs::util::rng::Rng;

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// WAL sync policy for the sweeps, from `GEOFS_TORTURE_SYNC`. The crash
/// contract is policy-independent (acked ⊆ recovered, nothing torn or
/// invented), so the same sweeps run under both ack protocols; CI's
/// crash-torture matrix crosses every seed with both values.
fn torture_sync_policy() -> SyncPolicy {
    match std::env::var("GEOFS_TORTURE_SYNC").as_deref() {
        Ok("group_commit") => SyncPolicy::GroupCommit { max_delay_us: 0, max_batch: 8 },
        _ => SyncPolicy::PerAppend,
    }
}

/// Write an audit document into `$GEOFS_TORTURE_AUDIT/<file>` when the
/// harness asked for artifacts.
fn audit_sink(file: &str, doc: &Json) {
    if let Ok(dir) = std::env::var("GEOFS_TORTURE_AUDIT") {
        let _ = std::fs::create_dir_all(&dir);
        let _ = std::fs::write(Path::new(&dir).join(file), doc.to_string());
    }
}

// ------------------------------------------------ storage-level sweep

const EVENTS: u64 = 96;

/// Deterministic record for global sequence `seq` (partition `seq % 2`,
/// offset `seq / 2`): recovery integrity is checked by regenerating the
/// record from its offset and requiring exact equality.
fn sev(seq: u64) -> StreamEvent {
    StreamEvent::new(seq, format!("cust_{:02}", seq % 8), seq as i64, seq as f32)
}

/// Drive the seeded storage workload — appends interleaved with
/// truncation, checkpoint commits and GC passes — until it finishes or
/// the injected crash kills the filesystem. Returns the acked appends
/// `(partition, offset, seq)` and the per-partition truncation floors
/// the driver explicitly requested.
fn drive_storage(
    vfs: Arc<dyn Vfs>,
    dir: &Path,
    events: u64,
    sync: SyncPolicy,
) -> (Vec<(usize, u64, u64)>, [u64; 2]) {
    let mut acked = Vec::new();
    let mut floors = [0u64; 2];
    let store = match DurableStore::open(vfs, dir, 0) {
        Ok(s) => s,
        Err(_) => return (acked, floors),
    };
    let log = match store.open_log::<StreamEvent>(
        "torture",
        2,
        DurableLogOptions { fragment_max_bytes: 256, sync, ..Default::default() },
    ) {
        Ok(l) => l,
        Err(_) => return (acked, floors),
    };
    for i in 0..events {
        let p = (i % 2) as usize;
        match log.append(p, sev(i)) {
            Ok(off) => acked.push((p, off, i)),
            Err(_) => return (acked, floors),
        }
        if i % 16 == 15 {
            // Consumer progress: reclaim the older half, then commit the
            // new floors with a checkpoint generation.
            for (p, floor) in floors.iter_mut().enumerate() {
                log.truncate_below(p, log.mem().high_water(p) / 2);
                *floor = (*floor).max(log.mem().base_offset(p));
            }
            if store.commit_checkpoint(i as i64, |_| {}).is_err() {
                return (acked, floors);
            }
        }
        if i % 48 == 47 && store.gc().is_err() {
            return (acked, floors);
        }
    }
    (acked, floors)
}

/// Reopen the crashed directory on the real filesystem and check the
/// full recovery contract; returns the post-GC audit document.
fn verify_storage_recovery(dir: &Path, acked: &[(usize, u64, u64)], floors: &[u64; 2]) -> Json {
    let store = DurableStore::open(Arc::new(RealFs), dir, 1)
        .expect("recovery after a crash (not corruption) must succeed");
    let log = store
        .open_log::<StreamEvent>(
            "torture",
            2,
            DurableLogOptions { fragment_max_bytes: 256, ..Default::default() },
        )
        .expect("crash recovery must never fail closed");
    let mut recovered: [HashMap<u64, StreamEvent>; 2] = [HashMap::new(), HashMap::new()];
    for (p, by_off) in recovered.iter_mut().enumerate() {
        for (off, e) in log.mem().read_from(p, 0, usize::MAX) {
            // Integrity: every recovered record is byte-identical to one
            // the driver actually appended — never torn, never invented.
            let seq = 2 * off + p as u64;
            assert_eq!(e, sev(seq), "p{p} off {off}: recovered record is not the appended one");
            by_off.insert(off, e);
        }
    }
    // The differential: acked ⊆ recovered (minus explicit truncation).
    for (p, off, seq) in acked {
        if *off < floors[*p] {
            continue; // reclaimed on purpose before the crash
        }
        assert!(
            recovered[*p].contains_key(off),
            "acked write lost: p{p} off {off} seq {seq}"
        );
    }
    // Two GC passes later the directory holds exactly the live set: no
    // orphan fragment, segment or stale manifest generation survives.
    store.gc().expect("GC mark pass");
    store.gc().expect("GC sweep pass");
    let audit = store.audit().expect("audit");
    let orphans = audit.get("orphans").as_arr().unwrap();
    assert!(orphans.is_empty(), "orphan files after two GC passes: {audit}");
    audit
}

#[test]
fn crash_point_sweep_recovers_every_acked_write() {
    let base_seed = env_u64("GEOFS_TORTURE_SEED", 42);
    let points = env_u64("GEOFS_TORTURE_POINTS", 20);
    let sync = torture_sync_policy();
    // Size the op space with an uncrashed run of the same workload.
    let total_ops = {
        let dir = TempDir::new("torture-dry");
        let fault = FaultFs::new(FaultConfig { seed: base_seed, ..Default::default() });
        let (acked, _) = drive_storage(fault.clone(), dir.path(), EVENTS, sync);
        assert_eq!(acked.len() as u64, EVENTS, "dry run must ack everything");
        fault.ops()
    };
    let mut rng = Rng::new(base_seed);
    let mut runs = Vec::new();
    let mut last_audit = Json::Null;
    for k in 0..points {
        let crash_at = 1 + rng.below(total_ops);
        let dir = TempDir::new("torture-crash");
        let fault = FaultFs::new(FaultConfig {
            seed: base_seed.wrapping_add(k + 1),
            fail_after_ops: Some(crash_at),
            ..Default::default()
        });
        let (acked, floors) = drive_storage(fault.clone(), dir.path(), EVENTS, sync);
        last_audit = verify_storage_recovery(dir.path(), &acked, &floors);
        runs.push(Json::obj(vec![
            ("crash_after_ops", Json::num(crash_at as f64)),
            ("acked", Json::num(acked.len() as f64)),
            ("crashed", Json::num(u64::from(fault.crashed()) as f64)),
        ]));
    }
    audit_sink(
        "storage-crash-sweep.json",
        &Json::obj(vec![
            ("base_seed", Json::num(base_seed as f64)),
            ("total_ops", Json::num(total_ops as f64)),
            ("runs", Json::Arr(runs)),
            ("last_recovery_audit", last_audit),
        ]),
    );
}

/// Group-commit boundary sweep: under `GroupCommit` a staged batch goes
/// down as one buffered write followed by one covering fsync — two
/// distinct filesystem ops. Crashing at *every* op in the workload's
/// opening window (plus sampled points across the rest of the op space)
/// deterministically lands crashes between the batched write and its
/// sync — the driver saw no ack, so a staged frame recovered there must
/// be byte-exact or absent, never invented — and directly after the
/// sync, before the waiters' wakeup — durable but unacked, which
/// at-least-once allows recovery to serve as long as it is the real
/// record. Runs under `GroupCommit` regardless of `GEOFS_TORTURE_SYNC`,
/// so the amortized path is always crash-tested.
#[test]
fn group_commit_crash_sweep_covers_write_sync_boundary() {
    let base_seed = env_u64("GEOFS_TORTURE_SEED", 42) ^ 0x06c0_0517;
    let sync = SyncPolicy::GroupCommit { max_delay_us: 0, max_batch: 8 };
    const GC_EVENTS: u64 = 32;
    let total_ops = {
        let dir = TempDir::new("torture-gc-dry");
        let fault = FaultFs::new(FaultConfig { seed: base_seed, ..Default::default() });
        let (acked, _) = drive_storage(fault.clone(), dir.path(), GC_EVENTS, sync);
        assert_eq!(acked.len() as u64, GC_EVENTS, "dry run must ack everything");
        fault.ops()
    };
    // Exhaustive over the opening window (fragment create + manifest
    // commit + the first several write→fsync pairs), sampled beyond it.
    let mut points: Vec<u64> = (1..=total_ops.min(40)).collect();
    let mut rng = Rng::new(base_seed);
    for _ in 0..env_u64("GEOFS_TORTURE_POINTS", 20).min(24) {
        points.push(1 + rng.below(total_ops));
    }
    for (k, crash_at) in points.into_iter().enumerate() {
        let dir = TempDir::new("torture-gc-crash");
        let fault = FaultFs::new(FaultConfig {
            seed: base_seed.wrapping_add(k as u64 + 1),
            fail_after_ops: Some(crash_at),
            ..Default::default()
        });
        let (acked, floors) = drive_storage(fault.clone(), dir.path(), GC_EVENTS, sync);
        verify_storage_recovery(dir.path(), &acked, &floors);
    }
}

/// Concurrent group-commit appenders racing a crash: each thread keeps
/// its own acked `(offset, seq)` list, and recovery must serve every
/// one of them byte-exact at that offset — a waiter woken before its
/// covering sync completed would surface here as a lost ack.
#[test]
fn group_commit_concurrent_appenders_crash_recovers_every_ack() {
    let base_seed = env_u64("GEOFS_TORTURE_SEED", 42) ^ 0x0acc_ed00;
    const THREADS: usize = 4;
    const PER_THREAD: u64 = 24;
    let opts = || DurableLogOptions {
        fragment_max_bytes: 256,
        sync: SyncPolicy::GroupCommit { max_delay_us: 200, max_batch: 0 },
        ..Default::default()
    };
    let drive = |vfs: Arc<dyn Vfs>, dir: &Path| -> Vec<(u64, u64)> {
        let Ok(store) = DurableStore::open(vfs, dir, 0) else { return Vec::new() };
        let Ok(log) = store.open_log::<StreamEvent>("torture", 1, opts()) else {
            return Vec::new();
        };
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let log = log.clone();
                std::thread::spawn(move || {
                    let mut acked = Vec::new();
                    for i in 0..PER_THREAD {
                        let seq = (t as u64) * 1000 + i;
                        match log.append(0, sev(seq)) {
                            Ok(off) => acked.push((off, seq)),
                            Err(_) => break,
                        }
                    }
                    acked
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    };
    // Size the op space with an uncrashed concurrent run.
    let total_ops = {
        let dir = TempDir::new("torture-gcc-dry");
        let fault = FaultFs::new(FaultConfig { seed: base_seed, ..Default::default() });
        let acked = drive(fault.clone(), dir.path());
        assert_eq!(acked.len(), THREADS * PER_THREAD as usize, "dry run must ack everything");
        fault.ops()
    };
    let mut rng = Rng::new(base_seed);
    for k in 0..6u64 {
        let crash_at = 1 + rng.below(total_ops);
        let dir = TempDir::new("torture-gcc");
        let fault = FaultFs::new(FaultConfig {
            seed: base_seed.wrapping_add(k + 1),
            fail_after_ops: Some(crash_at),
            ..Default::default()
        });
        let acked = drive(fault.clone(), dir.path());
        let store = DurableStore::open(Arc::new(RealFs), dir.path(), 1)
            .expect("recovery after a crash must succeed");
        let log = store
            .open_log::<StreamEvent>("torture", 1, DurableLogOptions::default())
            .expect("crash recovery must never fail closed");
        let mut by_off = HashMap::new();
        for (off, e) in log.mem().read_from(0, 0, usize::MAX) {
            assert_eq!(e, sev(e.seq), "recovered record is not an appended one");
            by_off.insert(off, e.seq);
        }
        for (off, seq) in &acked {
            assert_eq!(
                by_off.get(off),
                Some(seq),
                "acked concurrent write lost or misplaced: off {off} seq {seq}"
            );
        }
    }
}

// ------------------------------------------- corruption (not crashes)

/// Build a pristine single-partition log (several sealed fragments plus
/// an active one) and return the expected sequence list.
fn pristine_log(dir: &Path, events: u64) -> Vec<u64> {
    let store = DurableStore::open(Arc::new(RealFs), dir, 0).unwrap();
    let log = store
        .open_log::<StreamEvent>(
            "t",
            1,
            DurableLogOptions { fragment_max_bytes: 192, ..Default::default() },
        )
        .unwrap();
    for i in 0..events {
        log.append(0, sev(i)).unwrap();
    }
    (0..events).collect()
}

/// Snapshot every file in `dir` as `(name, bytes)`.
fn snapshot_files(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (e.file_name().to_string_lossy().into_owned(), std::fs::read(e.path()).unwrap())
        })
        .collect();
    out.sort();
    out
}

/// Open a damaged directory and read partition 0 back; `Ok` carries the
/// rooted manifest generation and the recovered sequence list.
fn read_all(dir: &Path) -> Result<(u64, Vec<u64>)> {
    let store = DurableStore::open(Arc::new(RealFs), dir, 0)?;
    let generation = store.manifest().generation;
    let log = store.open_log::<StreamEvent>("t", 1, DurableLogOptions::default())?;
    let seqs = log.mem().read_from(0, 0, usize::MAX).into_iter().map(|(_, e)| e.seq).collect();
    Ok((generation, seqs))
}

/// Plant `files` (with `target` replaced by `damaged`) in a scratch dir
/// and assert the corruption contract: recovery either fails closed
/// with a typed [`FsError::Corrupt`] or returns a valid prefix of
/// `expected` — never a damaged record, never an untyped error.
fn assert_damage_contained(
    files: &[(String, Vec<u8>)],
    target: &str,
    damaged: &[u8],
    expected: &[u64],
    what: &str,
) {
    let scratch = TempDir::new("torture-damage");
    for (n, b) in files {
        let data = if n.as_str() == target { damaged } else { &b[..] };
        std::fs::write(scratch.file(n), data).unwrap();
    }
    match read_all(scratch.path()) {
        Ok((_, seqs)) => assert!(
            expected.starts_with(&seqs),
            "{what}: recovered {seqs:?} is not a prefix of the pristine log"
        ),
        Err(FsError::Corrupt(_)) => {} // fail closed, typed
        Err(e) => panic!("{what}: failure is not typed corruption: {e}"),
    }
}

#[test]
fn fragment_truncation_fails_closed_or_recovers_prefix() {
    let src = TempDir::new("torture-trunc");
    let expected = pristine_log(src.path(), 14);
    let files = snapshot_files(src.path());
    for (name, bytes) in files.iter().filter(|(n, _)| n.ends_with(".frag")) {
        // Truncate at *every* byte boundary of every fragment file.
        for cut in 0..bytes.len() {
            assert_damage_contained(
                &files,
                name,
                &bytes[..cut],
                &expected,
                &format!("{name} truncated to {cut} bytes"),
            );
        }
    }
}

#[test]
fn fragment_bit_flips_never_serve_damaged_records() {
    let src = TempDir::new("torture-flip-frag");
    let expected = pristine_log(src.path(), 14);
    let files = snapshot_files(src.path());
    for (name, bytes) in files.iter().filter(|(n, _)| n.ends_with(".frag")) {
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 1 << (i % 8);
            assert_damage_contained(
                &files,
                name,
                &bad,
                &expected,
                &format!("{name} bit-flipped at byte {i}"),
            );
        }
    }
}

#[test]
fn manifest_bit_flips_fall_back_a_generation() {
    let src = TempDir::new("torture-flip-man");
    let expected = pristine_log(src.path(), 14);
    // Two extra checkpoint generations so the fallback chain has
    // headroom, then damage the newest root.
    let store = DurableStore::open(Arc::new(RealFs), src.path(), 0).unwrap();
    store.commit_checkpoint(1, |_| {}).unwrap();
    store.commit_checkpoint(2, |_| {}).unwrap();
    let newest_gen = store.manifest().generation;
    drop(store);
    let newest = geofs::storage::manifest::manifest_file_name(newest_gen);
    let files = snapshot_files(src.path());
    let bytes = &files.iter().find(|(n, _)| *n == newest).unwrap().1;
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 1 << (i % 8);
        let scratch = TempDir::new("torture-man-case");
        for (n, b) in &files {
            let data = if *n == newest { &bad } else { b };
            std::fs::write(scratch.file(n), data).unwrap();
        }
        // Every single-bit flip must be detected (magic, checksum or
        // decode), and an older intact generation must root recovery.
        let (generation, seqs) =
            read_all(scratch.path()).expect("fallback generation must root recovery");
        assert!(
            generation < newest_gen,
            "flip at byte {i}: damaged newest manifest must not stay the root"
        );
        assert!(
            expected.starts_with(&seqs),
            "flip at byte {i}: fallback recovered {seqs:?}, not a prefix"
        );
    }
}

// -------------------------------------------- coordinator-level sweep

/// Deterministic coordinator-level stream event for sequence `seq`.
fn cev(seq: u64) -> StreamEvent {
    StreamEvent::new(seq, format!("cust_{:02}", seq % 8), HOUR + seq as i64 * 60, seq as f32)
}

/// Open a durable `FeatureStore` over `vfs` with a registered streaming
/// table — the same fixture before and after the "crash".
fn coord_fixture(vfs: Arc<dyn Vfs>, dir: &Path) -> Result<(Arc<FeatureStore>, String)> {
    let durability = DurabilityOptions {
        dir: dir.to_path_buf(),
        fs: vfs,
        fragment_max_bytes: 512,
        sync: torture_sync_policy(),
        gc_period: None,
    };
    let fs = FeatureStore::open(
        Config::default_local(),
        OpenOptions { with_engine: false, durability: Some(durability), ..Default::default() },
    )?;
    fs.create_store("fs-torture")?;
    fs.create_entity(EntitySpec::new("customer", 1, &["customer_id"]))?;
    let table = fs.register_feature_set(
        FeatureSetSpec::rolling(
            "txn",
            1,
            "customer",
            SourceSpec::synthetic(0),
            Granularity(HOUR),
            3,
        ),
        Arc::new(FixedSource(Vec::new())),
        0,
    )?;
    fs.start_stream(&table, StreamConfig { partitions: 2, ..Default::default() })?;
    Ok((fs, table))
}

/// Ingest events one at a time (each `Ok` is a durability ack),
/// interleaved with polls and durable checkpoints, until the injected
/// crash stops the store. Returns the acked sequence numbers.
fn drive_coordinator(vfs: Arc<dyn Vfs>, dir: &Path, events: u64) -> Vec<u64> {
    let mut acked = Vec::new();
    let (fs, table) = match coord_fixture(vfs, dir) {
        Ok(x) => x,
        Err(_) => return acked, // crashed during open/registration
    };
    for i in 0..events {
        fs.clock.set(HOUR + i as i64 * 60);
        match fs.stream_ingest(&table, &[cev(i)]) {
            Ok(_) => acked.push(i),
            Err(_) => break,
        }
        if i % 15 == 14 && fs.poll_stream(&table).is_err() {
            break;
        }
        if i % 40 == 39 && fs.checkpoint_durable().is_err() {
            break;
        }
    }
    acked
}

/// Reopen the coordinator on the real filesystem and assert the
/// acked-ingest differential, then the GC/audit invariants.
fn verify_coordinator_recovery(dir: &Path, acked: &[u64]) -> Json {
    let (fs, table) =
        coord_fixture(Arc::new(RealFs), dir).expect("coordinator recovery must succeed");
    let log = fs.stream(&table).unwrap().log().clone();
    let mut seqs = HashSet::new();
    for p in 0..log.partitions() {
        for (_, e) in log.read_from(p, 0, usize::MAX) {
            assert_eq!(e, cev(e.seq), "recovered stream event is not the ingested one");
            seqs.insert(e.seq);
        }
    }
    for s in acked {
        assert!(seqs.contains(s), "acked stream ingest {s} lost across restart");
    }
    fs.gc_storage().expect("GC mark pass");
    fs.gc_storage().expect("GC sweep pass");
    let audit = fs.storage_audit().expect("audit");
    let orphans = audit.get("orphans").as_arr().unwrap();
    assert!(orphans.is_empty(), "orphan files after two GC passes: {audit}");
    audit
}

#[test]
fn coordinator_crash_torture_recovers_acked_stream_ingest() {
    let base_seed = env_u64("GEOFS_TORTURE_SEED", 42) ^ 0xc0ff_ee00;
    let points = env_u64("GEOFS_TORTURE_POINTS", 20).clamp(1, 8);
    let total_ops = {
        let dir = TempDir::new("torture-coord-dry");
        let fault = FaultFs::new(FaultConfig { seed: base_seed, ..Default::default() });
        let acked = drive_coordinator(fault.clone(), dir.path(), 120);
        assert_eq!(acked.len(), 120, "dry run must ack everything");
        fault.ops()
    };
    let mut rng = Rng::new(base_seed);
    let mut runs = Vec::new();
    let mut last_audit = Json::Null;
    for k in 0..points {
        let crash_at = 1 + rng.below(total_ops);
        let dir = TempDir::new("torture-coord");
        let fault = FaultFs::new(FaultConfig {
            seed: base_seed.wrapping_add(k + 1),
            fail_after_ops: Some(crash_at),
            ..Default::default()
        });
        let acked = drive_coordinator(fault.clone(), dir.path(), 120);
        last_audit = verify_coordinator_recovery(dir.path(), &acked);
        runs.push(Json::obj(vec![
            ("crash_after_ops", Json::num(crash_at as f64)),
            ("acked", Json::num(acked.len() as f64)),
            ("crashed", Json::num(u64::from(fault.crashed()) as f64)),
        ]));
    }
    audit_sink(
        "coordinator-crash-sweep.json",
        &Json::obj(vec![
            ("base_seed", Json::num(base_seed as f64)),
            ("total_ops", Json::num(total_ops as f64)),
            ("runs", Json::Arr(runs)),
            ("last_recovery_audit", last_audit),
        ]),
    );
}

#[test]
fn transient_io_errors_retry_without_loss() {
    let dir = TempDir::new("torture-transient");
    let fault = FaultFs::new(FaultConfig {
        seed: env_u64("GEOFS_TORTURE_SEED", 42) ^ 0x7a,
        transient_error_rate: 0.03,
        ..Default::default()
    });
    // Even open can hit a transient — retried like any driver retries.
    let mut opened = None;
    for _ in 0..50 {
        if let Ok(x) = coord_fixture(fault.clone(), dir.path()) {
            opened = Some(x);
            break;
        }
    }
    let (fs, table) = opened.expect("open must eventually succeed under transient faults");
    let policy = Backoff::immediate(32);
    for i in 0..120u64 {
        fs.clock.set(HOUR + i as i64 * 60);
        retry(&policy, || fs.stream_ingest(&table, &[cev(i)]).map(|_| ()))
            .expect("transient I/O errors must be retryable, not fatal");
        if i % 20 == 19 {
            let _ = retry(&policy, || fs.poll_stream(&table));
        }
    }
    retry(&policy, || fs.checkpoint_durable())
        .expect("checkpoint must succeed under transient faults");
    assert!(!fault.crashed(), "transient errors must never escalate to a crash");
    drop(fs);
    // Nothing acked under transient faults is lost across a restart.
    verify_coordinator_recovery(dir.path(), &(0..120).collect::<Vec<_>>());
}
