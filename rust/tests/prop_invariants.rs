//! Experiment E2 + coordinator invariants as property tests (in-tree
//! `testkit::prop` framework — proptest is unavailable offline).
//!
//! Each property runs hundreds of seeded random cases with shrinking.

use std::sync::Arc;

use geofs::offline_store::OfflineStore;
use geofs::online_store::OnlineStore;
use geofs::query::pit::{pit_lookup, Observation, PitConfig, PitIndex};
use geofs::scheduler::WindowTracker;
use geofs::testkit::prop::{forall, Gen};
use geofs::types::{FeatureRecord, FeatureWindow};
use geofs::util::json::Json;
use geofs::util::rng::Rng;

/// Compact record encoding for generation + shrinking:
/// (entity, event_ts, creation_delta>0, value-salt).
type R = (u64, i64, i64, i32);

fn to_rec(r: &R) -> FeatureRecord {
    // Value is a pure function of the uniqueness key: two generated
    // records with identical keys must carry identical values (as real
    // deterministic materialization guarantees), otherwise "first write
    // wins on no-op" makes delivery order observable by construction.
    let value = (r.0 as i64 * 31 + r.1 * 7 + r.2) as f32;
    FeatureRecord::new(r.0, r.1, r.1 + 1 + r.2.abs(), vec![value])
}

fn gen_records(max_len: usize) -> Gen<Vec<R>> {
    Gen::new(move |rng: &mut Rng| {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n)
            .map(|_| {
                (
                    rng.below(6),
                    rng.range(0, 500),
                    rng.range(0, 300),
                    rng.range(-100, 100) as i32,
                )
            })
            .collect()
    })
}

#[test]
fn prop_online_merge_order_independent() {
    // Alg 2 online: the converged per-entity state is independent of
    // delivery order and of duplicate delivery.
    forall("online-order-independent", 300, &gen_records(24), |rs| {
        let canonical = {
            let s = OnlineStore::new(2);
            for r in rs {
                s.merge("t", &[to_rec(r)], 0);
            }
            s.dump_table("t", 1_000_000)
        };
        // Shuffled + duplicated delivery.
        let mut rng = Rng::new(rs.len() as u64 + 1);
        let mut shuffled: Vec<R> = rs.clone();
        shuffled.extend(rs.iter().cloned()); // duplicates
        rng.shuffle(&mut shuffled);
        let s = OnlineStore::new(4);
        for r in &shuffled {
            s.merge("t", &[to_rec(r)], 0);
        }
        let got = s.dump_table("t", 1_000_000);
        if got == canonical {
            Ok(())
        } else {
            Err(format!("diverged: {got:?} vs {canonical:?}"))
        }
    });
}

#[test]
fn prop_online_state_is_eq2_of_offline() {
    // Merging the same stream into both stores: online equals the
    // offline max(event_ts, creation_ts) per entity.
    forall("online-is-eq2", 300, &gen_records(24), |rs| {
        let off = OfflineStore::new();
        let on = OnlineStore::new(2);
        for r in rs {
            let rec = to_rec(r);
            off.merge("t", std::slice::from_ref(&rec));
            on.merge("t", &[rec], 0);
        }
        for latest in off.latest_per_entity("t") {
            match on.get("t", latest.entity, 1_000_000) {
                Some(got) if got.version() == latest.version() => {}
                other => return Err(format!("entity {}: {other:?} vs {latest:?}", latest.entity)),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_offline_merge_idempotent_and_lossless() {
    forall("offline-idempotent", 300, &gen_records(24), |rs| {
        let off = OfflineStore::new();
        let recs: Vec<FeatureRecord> = rs.iter().map(to_rec).collect();
        off.merge("t", &recs);
        let count1 = off.row_count("t");
        off.merge("t", &recs); // replay the whole job
        if off.row_count("t") != count1 {
            return Err("replay changed row count".into());
        }
        // Every unique key present.
        let unique: std::collections::HashSet<_> = recs.iter().map(|r| r.unique_key()).collect();
        if unique.len() as u64 != count1 {
            return Err(format!("{} unique vs {count1} stored", unique.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_pit_index_matches_oracle() {
    let gen = Gen::new(|rng: &mut Rng| {
        let n = rng.below(30) as usize;
        let records: Vec<R> = (0..n)
            .map(|_| (rng.below(4), rng.range(0, 300), rng.range(0, 200), 0))
            .collect();
        records
    });
    forall("pit-index-oracle", 300, &gen, |rs| {
        let recs: Vec<FeatureRecord> = rs.iter().map(to_rec).collect();
        let idx = PitIndex::build(recs.clone());
        let mut rng = Rng::new(rs.len() as u64 * 31 + 7);
        for _ in 0..50 {
            let obs = Observation { entity: rng.below(5), ts: rng.range(0, 700) };
            let cfg = PitConfig {
                availability_slack: rng.range(0, 50),
                max_staleness: if rng.bool(0.5) { 0 } else { rng.range(1, 400) },
            };
            let fast = idx.lookup(obs, cfg).cloned();
            let slow = pit_lookup(&recs, obs, cfg);
            if fast != slow {
                return Err(format!("obs {obs:?} cfg {cfg:?}: {fast:?} vs {slow:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tracker_gaps_partition_window() {
    // gaps(w) ∪ covered-parts of w == w exactly, with no overlap.
    let gen = Gen::new(|rng: &mut Rng| {
        let n = rng.below(12) as usize;
        (0..n)
            .map(|_| {
                let a = rng.range(0, 200);
                let b = a + rng.range(1, 50);
                (a, b)
            })
            .collect::<Vec<(i64, i64)>>()
    });
    forall("tracker-gap-partition", 300, &gen, |windows| {
        let mut t = WindowTracker::new();
        for &(a, b) in windows {
            if let Ok(id) = t.try_claim(FeatureWindow::new(a, b)) {
                t.complete(id).map_err(|e| e.to_string())?;
            }
        }
        let probe = FeatureWindow::new(-20, 260);
        let gaps = t.gaps(probe);
        // Gaps are disjoint, sorted, inside the probe.
        for pair in gaps.windows(2) {
            if pair[0].end > pair[1].start {
                return Err(format!("gaps overlap: {pair:?}"));
            }
        }
        let gap_len: i64 = gaps.iter().map(|g| g.len()).sum();
        let covered: i64 = t
            .coverage()
            .iter()
            .filter_map(|c| c.intersect(&probe))
            .map(|c| c.len())
            .sum();
        if gap_len + covered != probe.len() {
            return Err(format!(
                "partition broken: gaps {gap_len} + covered {covered} != {}",
                probe.len()
            ));
        }
        // Every gap is genuinely unmaterialized.
        for g in &gaps {
            if t.is_materialized(g) {
                return Err(format!("gap {g} claims materialized"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_online_state_is_max_version_tuple() {
    // Eq. 2 stated directly: after any interleaving of upserts, the
    // record stored per entity is exactly the delivered record with
    // max(tuple(event_ts, creation_ts)) — computed here from the raw
    // input list, independent of any store machinery.
    forall("online-max-tuple", 300, &gen_records(32), |rs| {
        let mut rng = Rng::new(rs.len() as u64 ^ 0xabcd);
        let mut order: Vec<R> = rs.clone();
        rng.shuffle(&mut order);
        let s = OnlineStore::new(3);
        for r in &order {
            s.merge("t", &[to_rec(r)], 0);
        }
        let mut expected: std::collections::HashMap<u64, FeatureRecord> =
            std::collections::HashMap::new();
        for r in rs {
            let rec = to_rec(r);
            match expected.get(&rec.entity) {
                Some(b) if b.version() >= rec.version() => {}
                _ => {
                    expected.insert(rec.entity, rec);
                }
            }
        }
        for (entity, want) in &expected {
            match s.get("t", *entity, 1_000_000) {
                Some(got) if got.version() == want.version() => {}
                other => {
                    return Err(format!(
                        "entity {entity}: stored {other:?}, want version {:?}",
                        want.version()
                    ))
                }
            }
        }
        if s.len() != expected.len() {
            return Err(format!("{} resident vs {} entities", s.len(), expected.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_get_many_equals_point_gets() {
    // For every key set (present, absent, duplicated keys; with and
    // without TTL expiry in play), the batched read path returns exactly
    // what per-key point reads return, in order.
    forall("get-many-equals-gets", 300, &gen_records(32), |rs| {
        let s = OnlineStore::new(4);
        for r in rs {
            // written_at spread so TTL bites for some records only.
            s.merge("t", &[to_rec(r)], (r.1 % 7) * 50);
        }
        s.set_ttl("t", 200);
        let mut rng = Rng::new(rs.len() as u64 * 17 + 3);
        for _ in 0..10 {
            let n = rng.below(12) as usize;
            let keys: Vec<u64> = (0..n).map(|_| rng.below(9)).collect();
            let now = rng.range(0, 600);
            let batched = s.get_many("t", &keys, now);
            if batched.len() != keys.len() {
                return Err(format!("{} results for {} keys", batched.len(), keys.len()));
            }
            for (i, &k) in keys.iter().enumerate() {
                let point = s.get("t", k, now);
                if batched[i] != point {
                    return Err(format!(
                        "key {k} at now={now}: batched {:?} vs point {point:?}",
                        batched[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_online_scale_preserves_contents() {
    forall("scale-preserves", 120, &gen_records(40), |rs| {
        let s = OnlineStore::new(3);
        for r in rs {
            s.merge("t", &[to_rec(r)], 0);
        }
        let before = s.dump_table("t", 1_000_000);
        for shards in [1usize, 7, 16, 2] {
            s.scale_to(shards).map_err(|e| e.to_string())?;
            let after = s.dump_table("t", 1_000_000);
            if after != before {
                return Err(format!("resharding to {shards} changed contents"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    // Generator for arbitrary JSON trees (depth-bounded).
    fn gen_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.range(-1_000_000, 1_000_000) as f64) / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| *rng.pick(&['a', '"', '\\', 'é', '\n', 'z'])).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let gen = Gen::new(|rng: &mut Rng| vec![gen_json(rng, 3)]);
    forall("json-roundtrip", 400, &gen, |v| {
        let j = &v[0];
        let text = j.to_string();
        match Json::parse(&text) {
            Ok(back) if back == *j => Ok(()),
            Ok(back) => Err(format!("{j} reparsed as {back}")),
            Err(e) => Err(format!("{j} → '{text}' failed: {e}")),
        }
    });
}
