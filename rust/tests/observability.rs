//! Export-completeness + end-to-end observability integration test
//! (PR 8). One coordinator-driven workload exercises every subsystem
//! that publishes metrics — materialization, streaming (including a
//! backpressure shed), online serving (hits, misses, admission shed),
//! the PIT query engine, geo-replication, compaction, and the TTL
//! sweeper — then asserts that the Prometheus `export()` view covers
//! every name in [`names::ALL_STATIC`] plus the dynamic-suffix series
//! this deployment publishes. A metric registers on first touch, so a
//! name missing from the export means a driver stopped publishing (or
//! drifted off the canonical vocabulary in `monitor/names.rs`).
//!
//! The store runs with always-on tracing and a zero slow-op threshold,
//! so the same run also proves the `FeatureStore::slow_ops()` /
//! `recent_traces()` surface captures rendered span trees.

use std::time::{Duration, Instant};

use geofs::config::Config;
use geofs::coordinator::{DurabilityOptions, FeatureStore, OpenOptions};
use geofs::testkit::TempDir;
use geofs::monitor::names;
use geofs::monitor::sweeper::sweep_once;
use geofs::monitor::trace::TraceConfig;
use geofs::query::pit::PitConfig;
use geofs::serving::AdmissionConfig;
use geofs::sim::workload::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::stream::{StreamConfig, StreamEvent};
use geofs::types::time::DAY;
use geofs::types::{FeatureRecord, FsError, Timestamp};

/// Poll `cond` until it holds or `deadline` passes (background drivers
/// run on wall-clock periods; every wait here is bounded).
fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let end = Instant::now() + deadline;
    while Instant::now() < end {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

#[test]
fn export_covers_every_published_metric() {
    let days: i64 = 3;
    let dir = TempDir::new("obs-durable");
    let fs = FeatureStore::open(
        Config::default_geo(),
        OpenOptions {
            with_engine: false,
            geo_replication: true,
            // Durability on, so the WAL series (wal_sync_total,
            // wal_group_size, wal_ack_wait_us) register and export.
            durability: Some(DurabilityOptions::at(dir.path())),
            // Finite tenant budget with a trickle refill: the first
            // few batches are admitted, then the gate sheds.
            admission: Some(AdmissionConfig {
                tenant_rate: 0.001,
                tenant_burst: 64.0,
                max_inflight: 256,
                ..Default::default()
            }),
            // Trace everything and call everything slow, so the run
            // also proves the slow-op surface end to end.
            trace: TraceConfig { sample_every: 1, slow_threshold_us: 0, ring_capacity: 64 },
            ..Default::default()
        },
    )
    .unwrap();
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 16, days, ..Default::default() },
    )
    .unwrap();
    let history_end = days * DAY;
    fs.clock.set(history_end);

    // -- batch materialization → materialized_records / materialization_jobs.
    fs.materialize_tick(&w.txn_table).unwrap();

    // -- streaming: a tiny backlog bound so one oversized burst sheds
    //    (stream_shed_events), then a drained flow for the consumption
    //    counters and watermark gauges. One partition keeps the
    //    watermark (and therefore the skew/lag gauges) deterministic.
    fs.start_stream(
        &w.interactions_table,
        StreamConfig { partitions: 1, max_backlog_events: 8, ..Default::default() },
    )
    .unwrap();
    let ev = |seq: u64, hour: i64| {
        StreamEvent::new(seq, format!("cust_{:05}", seq % 16), history_end + hour * 3_600, 1.0)
    };
    let first: Vec<StreamEvent> = (0..6).map(|i| ev(i, i as i64)).collect();
    assert_eq!(fs.stream_ingest(&w.interactions_table, &first).unwrap(), 6);
    let burst: Vec<StreamEvent> = (6..16).map(|i| ev(i, i as i64)).collect();
    let shed = fs.stream_ingest(&w.interactions_table, &burst);
    assert!(
        matches!(shed, Err(FsError::Overloaded { .. })),
        "oversized burst past max_backlog_events must shed, got {shed:?}"
    );
    assert!(fs.metrics.counter(names::STREAM_SHED_EVENTS) > 0);
    fs.clock.set(history_end + 16 * 3_600);
    fs.drain_stream(&w.interactions_table).unwrap();
    assert!(
        fs.metrics.gauge(names::STREAM_WATERMARK_LAG_SECS).is_some(),
        "drained stream must publish its watermark lag"
    );

    // -- online serving: hits on the materialized daily table, misses on
    //    keys the hourly table never saw (interned but absent), and —
    //    once the 64-key tenant burst is spent — admission sheds.
    let home = fs.config.home_region().to_string();
    let keys: Vec<String> = (0..8).map(|i| format!("cust_{i:05}")).collect();
    let hit_reqs: Vec<(&str, &str)> =
        keys.iter().map(|k| (w.txn_table.as_str(), k.as_str())).collect();
    let hits = fs.get_online_many_mixed(&w.principal, &hit_reqs, &home).unwrap();
    assert!(hits.iter().any(|l| l.record.is_some()), "materialized reads must hit");
    let miss_keys: Vec<String> = (8..16).map(|i| format!("cust_{i:05}")).collect();
    let miss_reqs: Vec<(&str, &str)> =
        miss_keys.iter().map(|k| (w.interactions_table.as_str(), k.as_str())).collect();
    fs.get_online_many_mixed(&w.principal, &miss_reqs, &home).unwrap();
    assert!(fs.metrics.counter(names::SERVING_HITS) > 0);
    assert!(fs.metrics.counter(names::SERVING_MISSES) > 0);

    // -- offline PIT query → training_rows_served (before the admission
    //    budget is exhausted below).
    let obs: Vec<(String, Timestamp)> = w
        .observation_spine(16)
        .into_iter()
        .map(|(k, ts, _label)| (k, ts))
        .collect();
    fs.get_training_frame(
        &w.principal,
        None,
        &obs,
        &w.model_features(),
        PitConfig::default(),
        &home,
    )
    .unwrap();

    // -- admission overload: keep offering batches until the tenant
    //    bucket is dry (burst 64 keys, refill ~0) → admission_shed.
    let mut shed_seen = false;
    for _ in 0..40 {
        if fs.get_online_many_mixed(&w.principal, &hit_reqs, &home).is_err() {
            shed_seen = true;
            break;
        }
    }
    assert!(shed_seen, "tenant bucket must run dry and shed");
    assert!(fs.metrics.counter(names::ADMISSION_SHED) > 0);

    // -- geo-replication: one deterministic pump refreshes the
    //    per-region lag/backlog gauges; the background driver's
    //    pump_parallel sets the fan-out gauge on its own tick.
    fs.pump_replication();
    assert!(
        wait_until(Duration::from_secs(10), || {
            fs.metrics.gauge(names::REPL_APPLY_PARALLEL).is_some()
        }),
        "background replication driver never reported its parallel fan-out"
    );

    // -- compaction: six spill-sized merges seed six tier-0 segments
    //    (spill threshold 1024, fanin 4), then the background driver
    //    folds them and bumps the merge counters.
    for seg in 0..6i64 {
        let recs: Vec<FeatureRecord> = (0..1024)
            .map(|i| {
                let ts = seg * 100_000 + i;
                FeatureRecord::new((i % 64) as u64, ts, ts + 1, vec![seg as f32])
            })
            .collect();
        fs.offline.merge("obs_compact_seed", &recs);
    }
    assert!(
        wait_until(Duration::from_secs(10), || {
            fs.metrics.counter(names::COMPACTION_MERGES_TOTAL) > 0
        }),
        "background compaction driver never merged the seeded tier-0 segments"
    );

    // -- TTL sweep: expire the daily table's online rows and run one
    //    deterministic cycle → ttl_evicted_total + the sweep gauges.
    fs.online.set_ttl(&w.txn_table, 60);
    fs.clock.advance(DAY);
    let report = sweep_once(&fs.online, &fs.freshness, &fs.metrics, fs.clock.now());
    assert!(report.evicted > 0, "expired online rows must be reclaimed");

    // -- the point of the test: every canonical name is in the export.
    let export = fs.metrics.export();
    for name in names::ALL_STATIC {
        assert!(
            export.contains(&format!("# TYPE {name} ")),
            "canonical metric '{name}' missing from export():\n{export}"
        );
    }
    // Dynamic-suffix series this deployment publishes: per-replica
    // replication gauges, the tier-0 merge counter, and the serving
    // latency summaries (pre-registered for every access mechanism).
    let mut dynamic: Vec<String> = fs
        .config
        .regions
        .iter()
        .filter(|r| **r != home)
        .flat_map(|r| [names::repl_lag_secs(r), names::repl_backlog(r)])
        .collect();
    dynamic.push(names::compaction_merges_tier(0));
    for mech in ["local", "xregion", "replica"] {
        dynamic.push(names::serving_latency_us(mech));
        dynamic.push(names::serving_batch_latency_us(mech));
    }
    for name in &dynamic {
        assert!(
            export.contains(&format!("# TYPE {name} ")),
            "dynamic-suffix metric '{name}' missing from export():\n{export}"
        );
    }

    // -- slow-op surface: always-on tracing with a zero threshold means
    //    the rings hold completed span trees for the work above.
    let slow = fs.slow_ops();
    assert!(!slow.is_empty(), "zero-threshold tracing captured no slow ops");
    assert!(slow.iter().all(|t| !t.render().is_empty()));
    assert!(slow.len() <= 64, "slow-op ring must stay bounded");
}
