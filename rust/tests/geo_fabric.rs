//! The replication fabric's correctness contract (§4.1.2 + §3.1.2):
//!
//! * **Convergence** — driver-applied replicas reach exactly the state
//!   of synchronous home-store application, under duplicate delivery
//!   and out-of-order record versions (the differential guarantee of
//!   the single replication plane).
//! * **Per-region locking** — a blocked region's apply never stalls
//!   another region's (the global-cursor-lock pump this PR removed
//!   would deadlock the pinned scenario).
//! * **Read-your-writes** — a token-gated replica read never returns
//!   pre-token state, whatever the pump interleaving.
//! * **Policy routing on the public batched path** — `Strong` /
//!   `BoundedStaleness` / `ReadYourWrites` selectable through
//!   `FeatureStore::get_online_many_with`, with bounded staleness
//!   falling back to cross-region instead of serving stale data.
//! * **Failover under replication** — the home dies mid-backlog; the
//!   promoted region recovers every acked write from the fabric log and
//!   returns with a running replication driver whose staleness gauges
//!   drain to zero.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::exec::{RetryPolicy, ThreadPool};
use geofs::geo::access::{AccessMechanism, CrossRegionAccess, ReadConsistency};
use geofs::geo::failover::FailoverManager;
use geofs::geo::replication::{ReplicationDriver, ReplicationFabric};
use geofs::geo::topology::GeoTopology;
use geofs::governance::rbac::{Grant, Principal, Role};
use geofs::metadata::assets::{EntitySpec, FeatureSetSpec, SourceSpec};
use geofs::monitor::metrics::MetricsRegistry;
use geofs::offline_store::OfflineStore;
use geofs::online_store::OnlineStore;
use geofs::scheduler::Scheduler;
use geofs::source::synthetic::SyntheticSource;
use geofs::testkit::TempDir;
use geofs::types::time::{Granularity, DAY, HOUR};
use geofs::types::FeatureRecord;
use geofs::util::rng::Rng;
use geofs::util::Clock;

fn rec(entity: u64, event: i64, created: i64, v: f32) -> FeatureRecord {
    FeatureRecord::new(entity, event, created, vec![v])
}

#[test]
fn driver_applied_replicas_converge_to_home_state() {
    let mut rng = Rng::new(13);
    let home = Arc::new(OnlineStore::new(4));
    let eu = Arc::new(OnlineStore::new(4));
    let asia = Arc::new(OnlineStore::new(4));
    let fabric = ReplicationFabric::new(
        4,
        vec![("eu".into(), eu.clone(), 7), ("asia".into(), asia.clone(), 19)],
        None,
    );
    let clock = Clock::fixed(0);
    let driver = ReplicationDriver::spawn(fabric.clone(), clock.clone(), Duration::from_millis(1));

    let tables = ["t:1", "u:1", "v:1"];
    let mut touched: Vec<(String, u64)> = Vec::new();
    let mut now = 0i64;
    for _ in 0..250 {
        now += rng.range(0, 3);
        let table = tables[rng.below(3) as usize];
        // Out-of-order versions inside and across batches: event and
        // creation are drawn independently, so a later append can carry
        // an older version (Alg 2 must still converge identically).
        let recs: Vec<FeatureRecord> = (0..1 + rng.below(6))
            .map(|_| {
                let e = rng.below(40);
                touched.push((table.to_string(), e));
                rec(e, rng.range(0, 500), rng.range(0, 500), rng.f32())
            })
            .collect();
        home.merge(table, &recs, now);
        fabric.append(table, &recs, now).unwrap();
        if rng.below(4) == 0 {
            // At-least-once delivery: the same batch appended twice.
            fabric.append(table, &recs, now).unwrap();
        }
        clock.set(now);
    }
    // All lags elapse; the background driver must drain both regions.
    clock.set(now + 100);
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while (fabric.backlog("eu") > 0 || fabric.backlog("asia") > 0)
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(fabric.backlog("eu") + fabric.backlog("asia"), 0, "driver must drain");

    let read_at = now + 200;
    for (table, e) in &touched {
        let want = home.get(table, *e, read_at).expect("home has every merged entity");
        for (name, store) in [("eu", &eu), ("asia", &asia)] {
            let got = store
                .get(table, *e, read_at)
                .unwrap_or_else(|| panic!("{name} missing {table}/{e}"));
            assert_eq!(got.version(), want.version(), "{name} {table}/{e}");
            assert_eq!(got.values, want.values, "{name} {table}/{e}");
        }
    }
    drop(driver);
}

#[test]
fn blocked_region_does_not_stall_another_regions_apply() {
    let slow = Arc::new(OnlineStore::new(2));
    let fast = Arc::new(OnlineStore::new(2));
    let fabric = ReplicationFabric::new(
        2,
        vec![("slow".into(), slow, 0), ("fast".into(), fast.clone(), 0)],
        None,
    );
    for i in 0..5 {
        fabric.append("t", &[rec(i, i as i64, i as i64 + 1, 1.0)], 0).unwrap();
    }
    // Hold the slow region's cursor lock (a region stuck mid-merge) and
    // apply the fast region from under it. The pre-fabric LogTailer held
    // ONE mutex across every region's merge — this call would deadlock.
    fabric.while_region_locked("slow", || {
        let applied = fabric.pump_region("fast", 100);
        assert_eq!(applied, 5, "fast region must apply while slow is blocked");
    });
    assert_eq!(fabric.backlog("fast"), 0);
    assert_eq!(fabric.backlog("slow"), 5, "blocked region untouched");
    // Nothing is reclaimable while the slow region still needs the log.
    assert_eq!(fabric.truncate_applied(), 0);
    fabric.pump(100);
    assert_eq!(fabric.backlog("slow"), 0);
    assert_eq!(fabric.truncate_applied(), 5);
}

#[test]
fn parallel_pump_converges_fast_region_while_slow_region_is_blocked() {
    // Sequential `pump` walks regions on one thread: with the slow
    // region's cursor lock held it blocks before ever reaching the fast
    // region, so the fast region's convergence time is hostage to the
    // slow one. `pump_parallel` fans each region onto the pool — the
    // fast region must fully converge while the slow one is still
    // stuck, i.e. while `pump_parallel` as a whole has not returned.
    let slow = Arc::new(OnlineStore::new(2));
    let fast = Arc::new(OnlineStore::new(2));
    let fabric = ReplicationFabric::new(
        2,
        vec![("slow".into(), slow.clone(), 0), ("fast".into(), fast.clone(), 0)],
        None,
    );
    for i in 0..5u64 {
        fabric.append("t", &[rec(i, i as i64, i as i64 + 1, 1.0)], 0).unwrap();
    }
    let pump = fabric.while_region_locked("slow", || {
        let f2 = fabric.clone();
        let pump = std::thread::spawn(move || {
            let pool = ThreadPool::new(2);
            f2.pump_parallel(100, &pool)
        });
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while fabric.backlog("fast") > 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(fabric.backlog("fast"), 0, "fast region must converge while slow is blocked");
        for i in 0..5u64 {
            assert!(fast.get("t", i, 100).is_some(), "entity {i} missing on fast replica");
        }
        assert_eq!(fabric.backlog("slow"), 5, "blocked region untouched");
        pump
    });
    // Lock released: the slow region's task proceeds and the pump joins.
    let applied = pump.join().unwrap();
    assert_eq!(applied["fast"], 5);
    assert_eq!(applied["slow"], 5, "slow region applies once its lock frees");
    assert_eq!(fabric.backlog("slow"), 0);
    for i in 0..5u64 {
        assert!(slow.get("t", i, 100).is_some());
    }
    assert_eq!(fabric.truncate_applied(), 5);
}

#[test]
fn read_your_writes_never_returns_pre_token_state() {
    let mut rng = Rng::new(29);
    let topology = Arc::new(GeoTopology::default_four_region());
    let home = Arc::new(OnlineStore::new(4));
    let eu = Arc::new(OnlineStore::new(4));
    let fabric =
        ReplicationFabric::new(4, vec![("westeurope".into(), eu, 15)], None);
    let access = CrossRegionAccess {
        topology,
        home_region: "eastus".into(),
        home_store: home.clone(),
        fabric: Some(fabric.clone()),
        geo_fenced: false,
    };
    let mut now = 1_000i64;
    for i in 0..200i64 {
        let e = rng.below(10);
        // Monotone per-write versions: the freshest state for entity `e`
        // is always the most recent write.
        let r = rec(e, i, i + 1, i as f32);
        home.merge("t", &[r.clone()], now);
        let token = fabric.append("t", &[r], now).unwrap();
        // Arbitrary pump interleavings: sometimes nothing, sometimes a
        // partial prefix, sometimes fully caught up.
        if rng.below(3) == 0 {
            fabric.pump(now + rng.range(0, 40));
        }
        let out = access
            .lookup("westeurope", "t", e, now, &ReadConsistency::ReadYourWrites(token))
            .unwrap();
        let got = out.record.expect("a session always sees its own write");
        assert!(
            got.version() >= (i, i + 1),
            "pre-token state served at step {i}: got {:?} via {:?}",
            got.version(),
            out.mechanism
        );
        now += rng.range(0, 5);
    }
}

#[test]
fn consistency_policies_on_the_public_batched_path() {
    let fs = FeatureStore::open(
        Config::default_geo(),
        OpenOptions { with_engine: false, geo_replication: true, ..Default::default() },
    )
    .unwrap();
    fs.create_store("fs-geo").unwrap();
    fs.create_entity(EntitySpec::new("customer", 1, &["customer_id"])).unwrap();
    let alice = Principal("alice".into());
    fs.rbac.grant(Grant {
        principal: alice.clone(),
        store: "fs-geo".into(),
        role: Role::Admin,
        workspace: "ws".into(),
        workspace_region: "eastus".into(),
    });
    let spec = FeatureSetSpec::rolling(
        "txn",
        1,
        "customer",
        SourceSpec::synthetic(5),
        Granularity(HOUR),
        4,
    );
    let table = fs
        .register_feature_set(spec, Arc::new(SyntheticSource::new(5, 30)), 0)
        .unwrap();
    fs.clock.set(2 * DAY);
    fs.materialize_tick(&table).unwrap();
    let token = fs.session_token().expect("replication on");
    let keys = ["cust_00000", "cust_00001", "cust_00002"];

    // Writes are acked but not yet replicated (lag 30 s): every policy
    // that needs fresh data must cross; eventual reads may go stale.
    fs.clock.advance(10);
    let strong = fs
        .get_online_many_with(&alice, &table, &keys, "westeurope", &ReadConsistency::Strong)
        .unwrap();
    assert!(strong.iter().all(|o| o.mechanism == AccessMechanism::CrossRegion));
    assert!(strong.iter().all(|o| o.record.is_some()));
    assert!(strong.iter().all(|o| o.staleness_secs == 0));

    let eventual = fs
        .get_online_many(&alice, &table, &keys, "westeurope")
        .unwrap();
    assert!(eventual.iter().all(|o| o.mechanism == AccessMechanism::Replica));
    assert!(
        eventual.iter().all(|o| o.record.is_none()),
        "replica has not applied yet: eventual reads see the stale (empty) copy"
    );

    // Bounded staleness past its bound: fall back to cross-region
    // rather than serve data 10 s staler than the caller allows.
    let bounded = fs
        .get_online_many_with(
            &alice,
            &table,
            &keys,
            "westeurope",
            &ReadConsistency::BoundedStaleness(5),
        )
        .unwrap();
    assert!(bounded.iter().all(|o| o.mechanism == AccessMechanism::CrossRegion));
    assert!(bounded.iter().all(|o| o.record.is_some()));

    // Read-your-writes with an uncovered token: same fallback.
    let ryw = fs
        .get_online_many_with(
            &alice,
            &table,
            &keys,
            "westeurope",
            &ReadConsistency::ReadYourWrites(token.clone()),
        )
        .unwrap();
    assert!(ryw.iter().all(|o| o.mechanism == AccessMechanism::CrossRegion));
    assert!(ryw.iter().all(|o| o.record.is_some()));

    // The replica catches up: every policy now serves locally with the
    // same data the home would return.
    fs.clock.advance(600);
    fs.pump_replication();
    for policy in [
        ReadConsistency::BoundedStaleness(5),
        ReadConsistency::ReadYourWrites(token),
    ] {
        let out = fs
            .get_online_many_with(&alice, &table, &keys, "westeurope", &policy)
            .unwrap();
        assert!(out.iter().all(|o| o.mechanism == AccessMechanism::Replica), "{policy:?}");
        for (o, s) in out.iter().zip(&strong) {
            assert_eq!(
                o.record.as_ref().map(|r| r.unique_key()),
                s.record.as_ref().map(|r| r.unique_key()),
                "replica ≡ home once covered"
            );
        }
    }
}

#[test]
fn failover_under_replication_loses_no_acked_write() {
    let topology = Arc::new(GeoTopology::default_four_region());
    let fm = FailoverManager::new(topology.clone());
    let metrics = Arc::new(MetricsRegistry::new());

    let offline = Arc::new(OfflineStore::new());
    let home = Arc::new(OnlineStore::new(4));
    let westus = Arc::new(OnlineStore::new(4));
    let westeurope = Arc::new(OnlineStore::new(4));
    let fabric = ReplicationFabric::new(
        4,
        vec![("westus".into(), westus.clone(), 5), ("westeurope".into(), westeurope.clone(), 5)],
        Some(metrics.clone()),
    );

    let sched = |at: i64| {
        Scheduler::new(Arc::new(ThreadPool::new(2)), Clock::fixed(at), RetryPolicy::default())
    };
    let dir = TempDir::new("fo-stress");
    let table = "t:1";
    let mut acked: Vec<FeatureRecord> = Vec::new();
    let mut cp = None;
    for i in 0..40i64 {
        let batch =
            vec![rec(i as u64 % 7, i * 10, i * 10 + 1, i as f32), rec((i as u64 + 3) % 7, i * 10 + 2, i * 10 + 3, -i as f32)];
        offline.merge(table, &batch);
        home.merge(table, &batch, i);
        fabric.append(table, &batch, i).unwrap();
        acked.extend(batch);
        if i == 15 {
            // The periodic HA checkpoint — 24 batches post-date it.
            cp = Some(
                fm.checkpoint("eastus", &sched(15), &offline, dir.path().to_path_buf(), 15)
                    .unwrap(),
            );
        }
    }
    // Replicas apply a partial prefix, then the home dies mid-backlog.
    fabric.pump(20);
    assert!(fabric.backlog("westus") > 0, "must fail over mid-backlog");
    topology.set_down("eastus", true);

    let clock = Clock::fixed(100);
    // Replay fanned out over the shared pool — the stress path runs the
    // parallel replay end to end (equivalence vs sequential is pinned
    // separately in geo::failover's unit tests).
    let replay_pool = Arc::new(ThreadPool::new(3));
    let promoted = fm
        .failover_with(
            cp.as_ref().unwrap(),
            &sched(100),
            4,
            100,
            Some(&fabric),
            clock.clone(),
            Some(metrics.clone()),
            Some(&replay_pool),
        )
        .unwrap();
    assert_eq!(promoted.region, "westus");

    // Zero lost acked writes: the promoted online store holds the max
    // version per entity across ALL acked batches (checkpointed or
    // not, replicated or not), and the restored offline store holds
    // every acked row.
    let mut expect: HashMap<u64, FeatureRecord> = HashMap::new();
    for r in &acked {
        let slot = expect.entry(r.entity).or_insert_with(|| r.clone());
        if r.version() > slot.version() {
            *slot = r.clone();
        }
    }
    for (e, want) in &expect {
        let got = promoted
            .online
            .get(table, *e, 1_000)
            .unwrap_or_else(|| panic!("entity {e} lost in failover"));
        assert_eq!(got.version(), want.version(), "entity {e}");
        assert_eq!(got.values, want.values, "entity {e}");
    }
    assert_eq!(promoted.offline.row_count(table), acked.len() as u64, "offline acked rows");

    // The promoted region is a first-class home: its fabric replicates
    // onward to the survivor and the staleness gauges drain to zero.
    let nf = promoted.fabric.as_ref().unwrap();
    assert_eq!(nf.regions(), vec!["westeurope"]);
    nf.append(table, &[rec(99, 1_000, 1_001, 42.0)], clock.now()).unwrap();
    clock.advance(60); // past the survivor's lag
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while (nf.backlog("westeurope") > 0
        || metrics.gauge("repl_lag_secs_westeurope") != Some(0.0))
        && std::time::Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(nf.backlog("westeurope"), 0);
    assert_eq!(metrics.gauge("repl_lag_secs_westeurope"), Some(0.0));
    assert_eq!(metrics.gauge("repl_backlog_westeurope"), Some(0.0));
    assert_eq!(westeurope.get(table, 99, 2_000).unwrap().values[0], 42.0);
    // The retained log was forwarded through the new fabric, so the
    // surviving replica (whose old cursor trailed mid-backlog) has also
    // converged on every acked write — not just the new home.
    for (e, want) in &expect {
        let got = westeurope
            .get(table, *e, 2_000)
            .unwrap_or_else(|| panic!("survivor missing entity {e}"));
        assert_eq!(got.version(), want.version(), "survivor entity {e}");
    }
}

/// Regression (load-harness PR): log truncation must never outrun the
/// last offline checkpoint. Before the checkpoint floor existed,
/// `truncate_applied` reclaimed any entry every replica had applied —
/// including entries newer than the last HA checkpoint. A home crash
/// then restored from that checkpoint with nothing left in the log to
/// replay the gap, silently losing acked writes on the *promoted*
/// store. The floor (recorded by `FeatureStore::checkpoint` →
/// `ReplicationFabric::record_checkpoint`) keeps post-checkpoint
/// entries durable until the next checkpoint, so crash-restore replays
/// them.
#[test]
fn truncation_respects_checkpoint_floor_across_crash_restore() {
    let topology = Arc::new(GeoTopology::default_four_region());
    let fm = FailoverManager::new(topology.clone());
    let metrics = Arc::new(MetricsRegistry::new());

    let offline = Arc::new(OfflineStore::new());
    let home = Arc::new(OnlineStore::new(4));
    let westus = Arc::new(OnlineStore::new(4));
    let fabric = ReplicationFabric::new(
        2,
        vec![("westus".into(), westus.clone(), 5)],
        Some(metrics.clone()),
    );
    let sched = |at: i64| {
        Scheduler::new(Arc::new(ThreadPool::new(2)), Clock::fixed(at), RetryPolicy::default())
    };
    let dir = TempDir::new("cp-floor");
    let table = "t:1";

    // Batch A: acked, replicated, checkpointed.
    let a = vec![rec(1, 10, 11, 1.0), rec(2, 12, 13, 2.0)];
    offline.merge(table, &a);
    home.merge(table, &a, 10);
    fabric.append(table, &a, 10).unwrap();
    fabric.pump(20);
    let cp = fm.checkpoint("eastus", &sched(20), &offline, dir.path().to_path_buf(), 20).unwrap();
    fabric.record_checkpoint();

    // Batch B: acked + fully replicated, but NOT in the checkpoint.
    let b = vec![rec(7, 30, 31, 7.5)];
    offline.merge(table, &b);
    home.merge(table, &b, 30);
    fabric.append(table, &b, 30).unwrap();
    fabric.pump(40);
    assert_eq!(fabric.backlog("westus"), 0, "B fully applied before truncation");

    // Truncation reclaims A (below the floor) but must retain B even
    // though every replica has applied it.
    assert_eq!(fabric.truncate_applied(), 1, "only the pre-checkpoint batch is reclaimed");
    assert_eq!(fabric.log_len(), 1, "post-checkpoint batch survives for crash-restore");

    // Home dies; promote. The restored stores must hold batch B, which
    // only the retained log can supply (the checkpoint predates it).
    topology.set_down("eastus", true);
    let clock = Clock::fixed(100);
    let promoted = fm
        .failover_with(&cp, &sched(100), 2, 100, Some(&fabric), clock, Some(metrics.clone()), None)
        .unwrap();
    assert_eq!(promoted.region, "westus");
    let got = promoted.online.get(table, 7, 1_000).expect("post-checkpoint write survives crash");
    assert_eq!(got.values[0], 7.5);
    assert_eq!(got.event_ts, 30);
    assert_eq!(promoted.offline.row_count(table), 3, "offline restore covers A and B");
}
