//! Property tests for the admission-control layer (load-harness PR).
//!
//! Three laws, checked at the controller and at the public store API:
//!
//! 1. **Conservation** — every request is exactly served or shed; the
//!    controller's counters agree with the callers' tallies even under
//!    thread contention, and inflight drains to zero when permits drop.
//! 2. **Typed shedding** — overload surfaces as `FsError::Overloaded`
//!    (never a panic, never a silent drop) on the batched read path and
//!    the streaming ingest path alike.
//! 3. **Rate + burst bound** — over any window W the admitted count
//!    never exceeds `burst + rate·W` (+1 for boundary slop), for
//!    arbitrary monotone arrival patterns.

use std::thread;

use geofs::config::Config;
use geofs::coordinator::{FeatureStore, OpenOptions};
use geofs::serving::{AdmissionConfig, AdmissionController};
use geofs::sim::{ChurnWorkload, ChurnWorkloadConfig};
use geofs::stream::{StreamConfig, StreamEvent};
use geofs::types::time::DAY;
use geofs::types::FsError;
use geofs::util::rng::Rng;

#[test]
fn conservation_under_contention() {
    // Zero refill → exactly `burst` admissions fit, no matter how the
    // threads interleave.
    let ctrl = AdmissionController::new(
        AdmissionConfig { tenant_rate: 0.0, tenant_burst: 500.0, ..Default::default() },
        None,
    );
    const THREADS: usize = 8;
    const OPS: usize = 200;
    let (mut served, mut shed) = (0u64, 0u64);
    thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let ctrl = ctrl.clone();
                s.spawn(move || {
                    let (mut served, mut shed) = (0u64, 0u64);
                    for i in 0..OPS {
                        match ctrl.admit("tenant", "table", 1.0, (t * OPS + i) as u64) {
                            Ok(_permit) => served += 1,
                            Err(FsError::Overloaded { .. }) => shed += 1,
                            Err(e) => panic!("admission must shed typed, got: {e}"),
                        }
                    }
                    (served, shed)
                })
            })
            .collect();
        for h in handles {
            let (a, b) = h.join().unwrap();
            served += a;
            shed += b;
        }
    });
    assert_eq!(served + shed, (THREADS * OPS) as u64, "every request served xor shed");
    assert_eq!(served, 500, "zero-refill bucket admits exactly its burst");
    assert_eq!(ctrl.admitted(), served);
    assert_eq!(ctrl.shed_count(), shed);
    assert_eq!(ctrl.inflight(), 0, "dropped permits release their slots");
}

#[test]
fn admitted_never_exceeds_rate_window_plus_burst() {
    for seed in [1u64, 7, 42, 1337] {
        let mut rng = Rng::new(seed);
        let rate = 50.0 + rng.f64() * 200.0;
        let burst = 10.0 + rng.f64() * 90.0;
        let ctrl = AdmissionController::new(
            AdmissionConfig { tenant_rate: rate, tenant_burst: burst, ..Default::default() },
            None,
        );
        let mut now_us = 0u64;
        let mut admitted = 0u64;
        for _ in 0..5_000 {
            now_us += rng.below(2_000); // bursty arrivals, 0..2ms apart
            if ctrl.admit("t", "tbl", 1.0, now_us).is_ok() {
                admitted += 1;
            }
        }
        let window_secs = now_us as f64 / 1e6;
        let bound = burst + rate * window_secs + 1.0;
        assert!(
            (admitted as f64) <= bound,
            "seed {seed}: admitted {admitted} exceeds burst {burst:.1} + rate {rate:.1} × {window_secs:.3}s"
        );
        // And the budget is actually usable: at least the burst fits.
        assert!((admitted as f64) >= burst.floor(), "seed {seed}: budget unusable");
    }
}

#[test]
fn store_read_path_sheds_typed_overloaded_past_burst() {
    let fs = FeatureStore::open(
        Config::default_local(),
        OpenOptions {
            with_engine: false,
            admission: Some(AdmissionConfig {
                tenant_rate: 0.0,
                tenant_burst: 4.0,
                ..Default::default()
            }),
            ..Default::default()
        },
    )
    .unwrap();
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 8, days: 2, ..Default::default() },
    )
    .unwrap();
    fs.clock.set(2 * DAY);
    fs.materialize_tick(&w.txn_table).unwrap();
    let home = fs.config.home_region().to_string();
    let reqs: Vec<(&str, &str)> =
        vec![(w.txn_table.as_str(), "cust_00001"), (w.txn_table.as_str(), "cust_00002")];

    // Two 2-key batches fit the burst of 4 exactly...
    fs.get_online_many_mixed(&w.principal, &reqs, &home).unwrap();
    fs.get_online_many_mixed(&w.principal, &reqs, &home).unwrap();
    // ...the third sheds with the typed error on the public API.
    match fs.get_online_many_mixed(&w.principal, &reqs, &home) {
        Err(FsError::Overloaded { resource, reason }) => {
            assert!(resource.contains("ds-alice"), "tenant named in shed: {resource}");
            assert!(!reason.is_empty());
        }
        Ok(_) => panic!("expected typed Overloaded shed past the burst"),
        Err(e) => panic!("expected Overloaded, got: {e}"),
    }
}

#[test]
fn stream_ingest_sheds_on_backlog_bound_and_recovers() {
    let fs = FeatureStore::open(
        Config::default_local(),
        OpenOptions { with_engine: false, ..Default::default() },
    )
    .unwrap();
    let w = ChurnWorkload::install(
        &fs,
        ChurnWorkloadConfig { customers: 8, days: 1, ..Default::default() },
    )
    .unwrap();
    fs.clock.set(DAY);
    fs.start_stream(
        &w.interactions_table,
        StreamConfig { partitions: 2, max_backlog_events: 3, ..Default::default() },
    )
    .unwrap();
    let ev = |seq: u64| StreamEvent::new(seq, "cust_00001", DAY + seq as i64, 1.0);

    fs.stream_ingest(&w.interactions_table, &[ev(0), ev(1), ev(2)]).unwrap();
    match fs.stream_ingest(&w.interactions_table, &[ev(3)]) {
        Err(FsError::Overloaded { resource, .. }) => {
            assert!(resource.contains(&w.interactions_table), "stream named in shed: {resource}")
        }
        Ok(_) => panic!("expected backlog shed at the bound"),
        Err(e) => panic!("expected Overloaded, got: {e}"),
    }
    // Draining the backlog reopens admission — backpressure, not a latch.
    fs.poll_stream(&w.interactions_table).unwrap();
    fs.stream_ingest(&w.interactions_table, &[ev(3)]).unwrap();
}
