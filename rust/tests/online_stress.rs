//! Stress tests for the online store's lock-free read path: concurrent
//! writers, batched readers, TTL sweeps and live `scale_to` rebalances.
//!
//! Invariants under attack:
//! * no lost updates — after all writers join, every entity holds the
//!   max-version record that was written for it (Eq. 2);
//! * readers never panic, never see foreign entities, and never observe
//!   an entity's version move backwards (snapshot generations are
//!   monotonic per thread);
//! * TTL-expired entries are never returned, no matter how reads race
//!   with writes, eviction sweeps and rebalances.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use geofs::online_store::OnlineStore;
use geofs::types::{FeatureRecord, Timestamp};
use geofs::util::rng::Rng;

const ENTITIES: u64 = 64;
const WRITERS: u64 = 4;
const WRITES_PER_THREAD: u64 = 300;

fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
    FeatureRecord::new(entity, event, created, vec![v])
}

/// The record thread `t` writes at iteration `i`. Entities are shared
/// across threads; versions grow with `i` and tie-break on `t`.
fn written(t: u64, i: u64) -> FeatureRecord {
    let entity = i % ENTITIES;
    rec(entity, i as i64, 1_000 + (i as i64) * 8 + t as i64, (t * 1_000 + i) as f32)
}

/// Expected Eq. 2 winner for `entity` after all writers finish.
fn expected_version(entity: u64) -> (i64, i64) {
    // Largest i < WRITES_PER_THREAD with i % ENTITIES == entity; all
    // threads write it, the largest thread id wins the creation tie.
    let last_round = (WRITES_PER_THREAD - 1) / ENTITIES;
    let i_max = if last_round * ENTITIES + entity < WRITES_PER_THREAD {
        last_round * ENTITIES + entity
    } else {
        (last_round - 1) * ENTITIES + entity
    };
    (i_max as i64, 1_000 + (i_max as i64) * 8 + (WRITERS as i64 - 1))
}

#[test]
fn writers_readers_and_rebalance_race() {
    let store = Arc::new(OnlineStore::new(4));
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        // Writers: upsert point records (the materialization path).
        for t in 0..WRITERS {
            let store = store.clone();
            s.spawn(move || {
                for i in 0..WRITES_PER_THREAD {
                    store.merge("t", &[written(t, i)], 1_000);
                }
            });
        }
        // Rebalancer: resharding cycles while traffic flows.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let cycle = [1usize, 8, 2, 16, 3, 32, 5, 4];
                let mut k = 0;
                while !done.load(Ordering::Relaxed) {
                    store.scale_to(cycle[k % cycle.len()]).unwrap();
                    k += 1;
                    std::thread::yield_now();
                }
            });
        }
        // Readers: batched multi-gets; versions must be sane and
        // per-thread monotone per entity.
        let mut readers = Vec::new();
        for r in 0..4u64 {
            let store = store.clone();
            let done = done.clone();
            readers.push(s.spawn(move || {
                let mut rng = Rng::new(0xbeef ^ r);
                let mut last_seen = vec![(i64::MIN, i64::MIN); ENTITIES as usize];
                let mut observed = 0u64;
                while !done.load(Ordering::Relaxed) {
                    let n = 1 + rng.below(48) as usize;
                    let keys: Vec<u64> = (0..n).map(|_| rng.below(ENTITIES + 8)).collect();
                    let got = store.get_many("t", &keys, 1_500);
                    assert_eq!(got.len(), keys.len());
                    for (i, out) in got.iter().enumerate() {
                        let entity = keys[i];
                        if let Some(record) = out {
                            assert_eq!(record.entity, entity, "foreign entity in slot");
                            assert_eq!(
                                record.event_ts.rem_euclid(ENTITIES as i64),
                                entity as i64,
                                "record not from this entity's write stream"
                            );
                            let v = record.version();
                            let prev = last_seen[entity as usize];
                            assert!(
                                v >= prev,
                                "version went backwards for {entity}: {prev:?} then {v:?}"
                            );
                            last_seen[entity as usize] = v;
                            observed += 1;
                        }
                    }
                }
                observed
            }));
        }

        // Wait for writers by joining their side of the scope manually:
        // writers are the first WRITERS spawned threads; easiest is to
        // re-check convergence below after the scope ends, so here just
        // give readers some overlap time with writers then stop.
        while store.len() < ENTITIES as usize {
            std::thread::yield_now();
        }
        // Let traffic overlap the rebalancer a little longer.
        std::thread::sleep(std::time::Duration::from_millis(50));
        done.store(true, Ordering::Relaxed);
        let total_observed: u64 = readers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total_observed > 0, "readers must observe live records");
    });

    // No lost updates: every entity converged to the Eq. 2 max.
    assert_eq!(store.len(), ENTITIES as usize);
    for e in 0..ENTITIES {
        let got = store.get("t", e, 2_000).unwrap();
        assert_eq!(got.version(), expected_version(e), "entity {e}");
    }
    // Batched equals point after the dust settles, across one more scale.
    store.scale_to(7).unwrap();
    let keys: Vec<u64> = (0..ENTITIES + 8).collect();
    let batched = store.get_many("t", &keys, 2_000);
    for (i, &k) in keys.iter().enumerate() {
        assert_eq!(batched[i], store.get("t", k, 2_000), "key {k}");
    }
}

#[test]
fn ttl_expired_entries_never_returned_under_stress() {
    let store = Arc::new(OnlineStore::new(4));
    store.set_ttl("stale", 100);
    store.set_ttl("live", 1_000_000);
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        // Writers: "stale" records are written far in the past (always
        // expired at read time); "live" records are fresh.
        for t in 0..2u64 {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut i = 0u64;
                while !done.load(Ordering::Relaxed) {
                    store.merge("stale", &[written(t, i % 500)], 0); // expires at 100
                    store.merge("live", &[written(t, i % 500)], 450);
                    i += 1;
                }
            });
        }
        // Sweeper: active TTL eviction must not block or break readers.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    store.evict_expired(500);
                    std::thread::yield_now();
                }
            });
        }
        // Rebalancer.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut k = 2usize;
                while !done.load(Ordering::Relaxed) {
                    store.scale_to(1 + (k % 9)).unwrap();
                    k += 1;
                    std::thread::yield_now();
                }
            });
        }
        // Readers at now=500: "stale" must always be empty, "live" may
        // hit (and any hit must carry a live payload).
        let mut live_hits = 0u64;
        for _ in 0..2_000 {
            let keys: Vec<u64> = (0..32).collect();
            for out in store.get_many("stale", &keys, 500) {
                assert!(out.is_none(), "TTL-expired record served: {out:?}");
            }
            live_hits += store.get_many("live", &keys, 500).iter().flatten().count() as u64;
            assert!(store.get("stale", 3, 500).is_none());
        }
        done.store(true, Ordering::Relaxed);
        assert!(live_hits > 0, "live table must serve through the churn");
    });
}
