//! Stress tests for the online store's lock-free read path: concurrent
//! writers, batched readers, TTL sweeps and live `scale_to` rebalances.
//!
//! Invariants under attack:
//! * no lost updates — after all writers join, every entity holds the
//!   max-version record that was written for it (Eq. 2);
//! * readers never panic, never see foreign entities, and never observe
//!   an entity's version move backwards (snapshot generations are
//!   monotonic per thread);
//! * TTL-expired entries are never returned, no matter how reads race
//!   with writes, eviction sweeps and rebalances.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use geofs::online_store::OnlineStore;
use geofs::types::{FeatureRecord, Timestamp};
use geofs::util::rng::Rng;

const ENTITIES: u64 = 64;
const WRITERS: u64 = 4;
const WRITES_PER_THREAD: u64 = 300;

fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
    FeatureRecord::new(entity, event, created, vec![v])
}

/// The record thread `t` writes at iteration `i`. Entities are shared
/// across threads; versions grow with `i` and tie-break on `t`.
fn written(t: u64, i: u64) -> FeatureRecord {
    let entity = i % ENTITIES;
    rec(entity, i as i64, 1_000 + (i as i64) * 8 + t as i64, (t * 1_000 + i) as f32)
}

/// Expected Eq. 2 winner for `entity` after all writers finish.
fn expected_version(entity: u64) -> (i64, i64) {
    // Largest i < WRITES_PER_THREAD with i % ENTITIES == entity; all
    // threads write it, the largest thread id wins the creation tie.
    let last_round = (WRITES_PER_THREAD - 1) / ENTITIES;
    let i_max = if last_round * ENTITIES + entity < WRITES_PER_THREAD {
        last_round * ENTITIES + entity
    } else {
        (last_round - 1) * ENTITIES + entity
    };
    (i_max as i64, 1_000 + (i_max as i64) * 8 + (WRITERS as i64 - 1))
}

#[test]
fn writers_readers_and_rebalance_race() {
    let store = Arc::new(OnlineStore::new(4));
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        // Writers: upsert point records (the materialization path).
        for t in 0..WRITERS {
            let store = store.clone();
            s.spawn(move || {
                for i in 0..WRITES_PER_THREAD {
                    store.merge("t", &[written(t, i)], 1_000);
                }
            });
        }
        // Rebalancer: resharding cycles while traffic flows.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let cycle = [1usize, 8, 2, 16, 3, 32, 5, 4];
                let mut k = 0;
                while !done.load(Ordering::Relaxed) {
                    store.scale_to(cycle[k % cycle.len()]).unwrap();
                    k += 1;
                    std::thread::yield_now();
                }
            });
        }
        // Readers: batched multi-gets; versions must be sane and
        // per-thread monotone per entity.
        let mut readers = Vec::new();
        for r in 0..4u64 {
            let store = store.clone();
            let done = done.clone();
            readers.push(s.spawn(move || {
                let mut rng = Rng::new(0xbeef ^ r);
                let mut last_seen = vec![(i64::MIN, i64::MIN); ENTITIES as usize];
                let mut observed = 0u64;
                while !done.load(Ordering::Relaxed) {
                    let n = 1 + rng.below(48) as usize;
                    let keys: Vec<u64> = (0..n).map(|_| rng.below(ENTITIES + 8)).collect();
                    let got = store.get_many("t", &keys, 1_500);
                    assert_eq!(got.len(), keys.len());
                    for (i, out) in got.iter().enumerate() {
                        let entity = keys[i];
                        if let Some(record) = out {
                            assert_eq!(record.entity, entity, "foreign entity in slot");
                            assert_eq!(
                                record.event_ts.rem_euclid(ENTITIES as i64),
                                entity as i64,
                                "record not from this entity's write stream"
                            );
                            let v = record.version();
                            let prev = last_seen[entity as usize];
                            assert!(
                                v >= prev,
                                "version went backwards for {entity}: {prev:?} then {v:?}"
                            );
                            last_seen[entity as usize] = v;
                            observed += 1;
                        }
                    }
                }
                observed
            }));
        }

        // Wait for writers by joining their side of the scope manually:
        // writers are the first WRITERS spawned threads; easiest is to
        // re-check convergence below after the scope ends, so here just
        // give readers some overlap time with writers then stop.
        while store.len() < ENTITIES as usize {
            std::thread::yield_now();
        }
        // Let traffic overlap the rebalancer a little longer.
        std::thread::sleep(std::time::Duration::from_millis(50));
        done.store(true, Ordering::Relaxed);
        let total_observed: u64 = readers.into_iter().map(|h| h.join().unwrap()).sum();
        assert!(total_observed > 0, "readers must observe live records");
    });

    // No lost updates: every entity converged to the Eq. 2 max.
    assert_eq!(store.len(), ENTITIES as usize);
    for e in 0..ENTITIES {
        let got = store.get("t", e, 2_000).unwrap();
        assert_eq!(got.version(), expected_version(e), "entity {e}");
    }
    // Batched equals point after the dust settles, across one more scale.
    store.scale_to(7).unwrap();
    let keys: Vec<u64> = (0..ENTITIES + 8).collect();
    let batched = store.get_many("t", &keys, 2_000);
    for (i, &k) in keys.iter().enumerate() {
        assert_eq!(batched[i], store.get("t", k, 2_000), "key {k}");
    }
}

/// A self-consistent record for the torn-read test: every field is a
/// function of `k`, so any cross-write mixture of fields is detectable.
fn consistent(k: i64) -> FeatureRecord {
    FeatureRecord::new(7, k, k + 1, vec![k as f32, (2 * k) as f32, -(k as f32)])
}

#[test]
fn torn_reads_never_observed() {
    // One writer hammers a single entity (every write hits the same
    // seqlock bucket) while readers spin on it. A reader must always see
    // one write's fields as a unit — event_ts, creation_ts and the value
    // payload from the same `consistent(k)` — never a mixture of two
    // writes. This is the property the bucket stamp protocol exists for;
    // a torn composite here is exactly what the old RwLock prevented.
    let store = Arc::new(OnlineStore::new(1));
    store.merge("t", &[consistent(0)], 1_000);
    let done = Arc::new(AtomicBool::new(false));
    std::thread::scope(|s| {
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut k = 1i64;
                while !done.load(Ordering::Relaxed) {
                    // Monotone versions: every write overrides in place,
                    // and the arena fill forces periodic shard rebuilds,
                    // so republication is exercised under the readers too.
                    store.merge("t", &[consistent(k)], 1_000);
                    k += 1;
                }
            });
        }
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let store = store.clone();
                s.spawn(move || {
                    for _ in 0..20_000 {
                        let got = store.get("t", 7, 1_500).expect("entity 7 always present");
                        let k = got.event_ts;
                        assert_eq!(got.creation_ts, k + 1, "torn creation_ts at k={k}");
                        assert_eq!(
                            &got.values[..],
                            &[k as f32, (2 * k) as f32, -(k as f32)],
                            "torn value payload at k={k}"
                        );
                    }
                })
            })
            .collect();
        for r in readers {
            r.join().unwrap();
        }
        done.store(true, Ordering::Relaxed);
    });
}

#[test]
fn eight_thread_read_write_scale_ttl_stress() {
    // 8 threads: 3 writers, 2 readers, a rebalancer, a TTL flipper and
    // an eviction sweeper, all on one table. Mid-run reads may or may
    // not hit (the TTL flips under them) but must always be internally
    // sane; after the churn stops, a reconciliation batch with versions
    // above everything written must converge exactly (evictions and
    // rebalances lose no *newest* data that is re-asserted).
    const STRESS_ENTITIES: u64 = 48;
    let store = Arc::new(OnlineStore::new(4));
    store.set_ttl("t", 1 << 40);
    let done = Arc::new(AtomicBool::new(false));
    std::thread::scope(|s| {
        for t in 0..3u64 {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut i = 0u64;
                while !done.load(Ordering::Relaxed) {
                    let e = i % STRESS_ENTITIES;
                    store.merge(
                        "t",
                        &[rec(e, i as i64, (i as i64) * 8 + t as i64, (t * 1_000 + i) as f32)],
                        1_000,
                    );
                    i += 1;
                }
            });
        }
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let cycle = [1usize, 6, 2, 12, 3];
                let mut k = 0;
                while !done.load(Ordering::Relaxed) {
                    store.scale_to(cycle[k % cycle.len()]).unwrap();
                    k += 1;
                    std::thread::yield_now();
                }
            });
        }
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut flip = false;
                while !done.load(Ordering::Relaxed) {
                    store.set_ttl("t", if flip { 10 } else { 1 << 40 });
                    flip = !flip;
                    std::thread::yield_now();
                }
            });
        }
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    store.evict_expired(1_200);
                    std::thread::yield_now();
                }
            });
        }
        let readers: Vec<_> = (0..2u64)
            .map(|r| {
                let store = store.clone();
                let done = done.clone();
                s.spawn(move || {
                    let mut rng = Rng::new(0xfeed ^ r);
                    while !done.load(Ordering::Relaxed) {
                        let keys: Vec<u64> =
                            (0..16).map(|_| rng.below(STRESS_ENTITIES + 4)).collect();
                        for (i, out) in store.get_many("t", &keys, 1_050).iter().enumerate() {
                            if let Some(record) = out {
                                assert_eq!(record.entity, keys[i], "foreign entity in slot");
                                assert_eq!(
                                    record.event_ts.rem_euclid(STRESS_ENTITIES as i64),
                                    keys[i] as i64,
                                    "record not from this entity's write stream"
                                );
                            }
                        }
                    }
                })
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(80));
        done.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
    });
    // Reconciliation: versions above anything the writers produced.
    store.set_ttl("t", 1 << 40);
    let reconcile: Vec<FeatureRecord> = (0..STRESS_ENTITIES)
        .map(|e| rec(e, 1 << 30, (1 << 30) + 1, e as f32))
        .collect();
    store.merge("t", &reconcile, 2_000);
    store.scale_to(5).unwrap();
    for e in 0..STRESS_ENTITIES {
        let got = store.get("t", e, 2_100).unwrap();
        assert_eq!(got.version(), (1 << 30, (1 << 30) + 1), "entity {e}");
        assert_eq!(got.values[0], e as f32);
    }
}

/// Single-threaded differential oracle: a plain `HashMap` model of
/// Eq. 2 + TTL semantics. Every public operation must agree exactly.
#[derive(Default)]
struct Oracle {
    /// table → entity → (event_ts, creation_ts, written_at, values).
    tables: HashMap<String, HashMap<u64, (i64, i64, i64, Vec<f32>)>>,
    ttls: HashMap<String, i64>,
}

impl Oracle {
    fn ttl(&self, table: &str) -> i64 {
        self.ttls.get(table).copied().unwrap_or(i64::MAX)
    }

    fn live(&self, table: &str, written_at: i64, now: i64) -> bool {
        let ttl = self.ttl(table);
        ttl == i64::MAX || now - written_at < ttl
    }

    /// (inserted, skipped) — override counts as inserted, like the store.
    fn merge(&mut self, table: &str, records: &[FeatureRecord], now: i64) -> (u64, u64) {
        let t = self.tables.entry(table.to_string()).or_default();
        let (mut ins, mut skip) = (0, 0);
        for r in records {
            match t.get(&r.entity) {
                Some(&(ev, cr, _, _)) if r.version() <= (ev, cr) => skip += 1,
                _ => {
                    t.insert(r.entity, (r.event_ts, r.creation_ts, now, r.values.to_vec()));
                    ins += 1;
                }
            }
        }
        (ins, skip)
    }

    fn get(&self, table: &str, entity: u64, now: i64) -> Option<(i64, i64, Vec<f32>)> {
        let (ev, cr, wr, v) = self.tables.get(table)?.get(&entity)?;
        self.live(table, *wr, now).then(|| (*ev, *cr, v.clone()))
    }

    fn evict_expired(&mut self, now: i64) -> u64 {
        let mut n = 0;
        for (name, t) in self.tables.iter_mut() {
            let ttl = self.ttls.get(name).copied().unwrap_or(i64::MAX);
            if ttl == i64::MAX {
                continue;
            }
            let before = t.len();
            t.retain(|_, &mut (_, _, wr, _)| now - wr < ttl);
            n += (before - t.len()) as u64;
        }
        n
    }

    fn len(&self) -> usize {
        self.tables.values().map(|t| t.len()).sum()
    }

    fn dump(&self, table: &str, now: i64) -> Vec<(u64, i64, i64, Vec<f32>)> {
        let mut out: Vec<_> = self
            .tables
            .get(table)
            .map(|t| {
                t.iter()
                    .filter(|(_, &(_, _, wr, _))| self.live(table, wr, now))
                    .map(|(&e, (ev, cr, _, v))| (e, *ev, *cr, v.clone()))
                    .collect()
            })
            .unwrap_or_default();
        out.sort_by_key(|r| r.0);
        out
    }
}

#[test]
fn store_matches_hashmap_oracle_over_random_ops() {
    let mut rng = Rng::new(0x5e91_10c4);
    let store = OnlineStore::new(3);
    let mut oracle = Oracle::default();
    let tables = ["a", "b"];
    let mut now = 1_000i64;
    for step in 0..3_000 {
        now += rng.range(0, 5);
        let table = tables[rng.below(2) as usize];
        match rng.below(10) {
            // Batch merge (colliding keys, small timestamp ranges force
            // frequent version ties and overrides).
            0..=3 => {
                let batch: Vec<FeatureRecord> = (0..1 + rng.below(12))
                    .map(|_| {
                        rec(rng.below(32), rng.range(0, 40), rng.range(0, 40), rng.f32())
                    })
                    .collect();
                let m = store.merge(table, &batch, now);
                assert_eq!(
                    (m.inserted, m.skipped),
                    oracle.merge(table, &batch, now),
                    "merge stats diverged at step {step}"
                );
            }
            4..=5 => {
                let keys: Vec<u64> = (0..rng.below(40)).map(|_| rng.below(40)).collect();
                let got = store.get_many(table, &keys, now);
                for (i, &k) in keys.iter().enumerate() {
                    let want = oracle.get(table, k, now);
                    let have =
                        got[i].as_ref().map(|r| (r.event_ts, r.creation_ts, r.values.to_vec()));
                    assert_eq!(have, want, "get_many({table}, {k}) diverged at step {step}");
                }
            }
            6 => {
                let k = rng.below(40);
                let have = store
                    .get(table, k, now)
                    .map(|r| (r.event_ts, r.creation_ts, r.values.to_vec()));
                assert_eq!(have, oracle.get(table, k, now), "get diverged at step {step}");
            }
            7 => {
                let ttl = [5, 20, i64::MAX][rng.below(3) as usize];
                store.set_ttl(table, ttl);
                oracle.ttls.insert(table.to_string(), ttl);
            }
            8 => {
                assert_eq!(
                    store.evict_expired(now),
                    oracle.evict_expired(now),
                    "evict count diverged at step {step}"
                );
            }
            _ => {
                store.scale_to(1 + rng.below(8) as usize).unwrap();
                let dump = store.dump_table(table, now);
                let have: Vec<_> = dump
                    .iter()
                    .map(|r| (r.entity, r.event_ts, r.creation_ts, r.values.to_vec()))
                    .collect();
                assert_eq!(have, oracle.dump(table, now), "dump diverged at step {step}");
            }
        }
        assert_eq!(store.len(), oracle.len(), "len diverged at step {step}");
    }
}

#[test]
fn ttl_expired_entries_never_returned_under_stress() {
    let store = Arc::new(OnlineStore::new(4));
    store.set_ttl("stale", 100);
    store.set_ttl("live", 1_000_000);
    let done = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        // Writers: "stale" records are written far in the past (always
        // expired at read time); "live" records are fresh.
        for t in 0..2u64 {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut i = 0u64;
                while !done.load(Ordering::Relaxed) {
                    store.merge("stale", &[written(t, i % 500)], 0); // expires at 100
                    store.merge("live", &[written(t, i % 500)], 450);
                    i += 1;
                }
            });
        }
        // Sweeper: active TTL eviction must not block or break readers.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    store.evict_expired(500);
                    std::thread::yield_now();
                }
            });
        }
        // Rebalancer.
        {
            let store = store.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut k = 2usize;
                while !done.load(Ordering::Relaxed) {
                    store.scale_to(1 + (k % 9)).unwrap();
                    k += 1;
                    std::thread::yield_now();
                }
            });
        }
        // Readers at now=500: "stale" must always be empty, "live" may
        // hit (and any hit must carry a live payload).
        let mut live_hits = 0u64;
        for _ in 0..2_000 {
            let keys: Vec<u64> = (0..32).collect();
            for out in store.get_many("stale", &keys, 500) {
                assert!(out.is_none(), "TTL-expired record served: {out:?}");
            }
            live_hits += store.get_many("live", &keys, 500).iter().flatten().count() as u64;
            assert!(store.get("stale", 3, 500).is_none());
        }
        done.store(true, Ordering::Relaxed);
        assert!(live_hits > 0, "live table must serve through the churn");
    });
}
