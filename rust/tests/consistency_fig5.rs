//! Experiments E1–E3: Fig 5 offline/online consistency semantics and
//! eventual consistency under injected merge failures (§4.5.2–§4.5.4).

use std::sync::Arc;

use geofs::materialize::bootstrap_offline_to_online;
use geofs::materialize::merge::{DualStoreMerger, FaultInjector};
use geofs::metadata::assets::MaterializationPolicy;
use geofs::offline_store::OfflineStore;
use geofs::online_store::OnlineStore;
use geofs::exec::RetryPolicy;
use geofs::types::{FeatureRecord, FeatureWindow, Timestamp};
use geofs::util::rng::Rng;
use geofs::util::Clock;

fn rec(entity: u64, event: Timestamp, created: Timestamp, v: f32) -> FeatureRecord {
    FeatureRecord::new(entity, event, created, vec![v])
}

/// The paper's exact Fig 5 scenario.
#[test]
fn fig5_exact_scenario() {
    let offline = Arc::new(OfflineStore::new());
    let online = Arc::new(OnlineStore::new(2));
    let merger = DualStoreMerger::new(
        offline.clone(),
        online.clone(),
        FaultInjector::none(),
        RetryPolicy::default(),
        Clock::fixed(0),
    );
    let policy = MaterializationPolicy::default();
    let (t0, t1, t2) = (100, 200, 300);
    let (c0, c1, c2, c3) = (110, 210, 310, 400);
    assert!(c3 > c2 && c2 > c1 && c1 > c0); // paper's t3' > t2' > t1' > t0'

    // T1: R0, R1, R2.
    for r in [rec(1, t0, c0, 0.0), rec(1, t1, c1, 1.0), rec(1, t2, c2, 2.0)] {
        merger.merge("t", &[r.clone()], &policy, r.creation_ts).unwrap();
    }
    assert_eq!(offline.scan("t", FeatureWindow::new(0, 1_000)).len(), 3, "offline has R0,R1,R2");
    assert_eq!(online.get("t", 1, 1_000).unwrap().version(), (t2, c2), "online has R2");

    // T2: late-arriving R3 = {event t1, creation t3'}.
    merger.merge("t", &[rec(1, t1, c3, 3.0)], &policy, c3).unwrap();
    assert_eq!(
        offline.scan("t", FeatureWindow::new(0, 1_000)).len(),
        4,
        "offline has all 4 records"
    );
    assert_eq!(
        online.get("t", 1, 1_000).unwrap().version(),
        (t2, c2),
        "online still has R2 (R3's event_ts is older)"
    );
}

/// Delivery-order independence: any interleaving of the same merges
/// converges both stores to identical final states.
#[test]
fn consistency_under_arbitrary_merge_order() {
    let records = vec![
        rec(1, 100, 110, 0.0),
        rec(1, 200, 210, 1.0),
        rec(1, 200, 400, 2.0),
        rec(1, 300, 310, 3.0),
        rec(2, 100, 120, 4.0),
        rec(2, 50, 500, 5.0),
    ];
    let mut rng = Rng::new(12);
    let mut reference_online: Option<Vec<(u64, (i64, i64))>> = None;
    for trial in 0..20 {
        let mut order = records.clone();
        rng.shuffle(&mut order);
        let offline = Arc::new(OfflineStore::new());
        let online = Arc::new(OnlineStore::new(4));
        let merger = DualStoreMerger::new(
            offline.clone(),
            online.clone(),
            FaultInjector::none(),
            RetryPolicy::default(),
            Clock::fixed(0),
        );
        for r in &order {
            merger
                .merge("t", std::slice::from_ref(r), &MaterializationPolicy::default(), r.creation_ts)
                .unwrap();
        }
        assert_eq!(offline.row_count("t"), 6, "offline keeps all (trial {trial})");
        let state: Vec<(u64, (i64, i64))> = online
            .dump_table("t", 10_000)
            .into_iter()
            .map(|r| (r.entity, r.version()))
            .collect();
        match &reference_online {
            None => reference_online = Some(state),
            Some(want) => assert_eq!(&state, want, "trial {trial} diverged"),
        }
    }
    let want = reference_online.unwrap();
    assert_eq!(want, vec![(1, (300, 310)), (2, (100, 120))]);
}

/// E3: under injected transient failures with retries, both stores
/// converge; with a persistently failing sink, the job-level retry
/// (re-merge of the same records) heals the divergence.
#[test]
fn eventual_consistency_with_fault_injection() {
    for &p in &[0.1, 0.3, 0.5] {
        let offline = Arc::new(OfflineStore::new());
        let online = Arc::new(OnlineStore::new(4));
        let merger = DualStoreMerger::new(
            offline.clone(),
            online.clone(),
            FaultInjector::with_rates(99, p, p),
            RetryPolicy { max_attempts: 30, ..Default::default() },
            Clock::fixed(0),
        );
        let records: Vec<FeatureRecord> =
            (0..200).map(|i| rec(i % 20, 100 + (i as i64 / 20) * 100, 1_000 + i as i64, i as f32)).collect();
        // Merge in batches (like jobs); job-level retry on failure.
        for chunk in records.chunks(25) {
            let mut attempts = 0;
            loop {
                attempts += 1;
                match merger.merge("t", chunk, &MaterializationPolicy::default(), 2_000) {
                    Ok(_) => break,
                    Err(_) if attempts < 50 => continue,
                    Err(e) => panic!("failed to converge at p={p}: {e}"),
                }
            }
        }
        // Convergence: offline holds every unique record; online holds the
        // Eq. 2 max per entity.
        assert_eq!(offline.row_count("t"), 200, "p={p}");
        for latest in offline.latest_per_entity("t") {
            let got = online.get("t", latest.entity, 10_000).unwrap();
            assert_eq!(got.version(), latest.version(), "p={p}");
        }
    }
}

/// §4.5.5 bootstrap both ways, composed with Fig 5 data.
#[test]
fn bootstrap_second_store_reaches_parity() {
    let offline = Arc::new(OfflineStore::new());
    // Offline-only phase.
    offline.merge(
        "t",
        &[rec(1, 100, 110, 0.0), rec(1, 200, 210, 1.0), rec(1, 200, 400, 2.0), rec(2, 50, 60, 3.0)],
    );
    // Enable online later → bootstrap.
    let online = Arc::new(OnlineStore::new(2));
    let stats = bootstrap_offline_to_online(&offline, &online, "t", 1_000);
    assert_eq!(stats.inserted, 2);
    assert_eq!(online.get("t", 1, 2_000).unwrap().version(), (200, 400));
    assert_eq!(online.get("t", 2, 2_000).unwrap().version(), (50, 60));

    // Subsequent merges keep both consistent without re-bootstrap.
    let merger = DualStoreMerger::new(
        offline.clone(),
        online.clone(),
        FaultInjector::none(),
        RetryPolicy::default(),
        Clock::fixed(0),
    );
    merger
        .merge("t", &[rec(1, 300, 500, 9.0)], &MaterializationPolicy::default(), 500)
        .unwrap();
    assert_eq!(online.get("t", 1, 2_000).unwrap().version(), (300, 500));
    assert_eq!(offline.row_count("t"), 5);
}
